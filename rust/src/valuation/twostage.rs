//! Two-stage scan-then-rescore query engine over a quantized store.
//!
//! Stage 1 scans the int8 quantized copy of the corpus
//! ([`QuantShardedStore`]) with the i32-accumulating block-dot kernel —
//! 4x less memory bandwidth than the f32 scan — and keeps, per test row, a
//! candidate pool of `rescore_factor × topk` rows by approximate score.
//! Stage 2 rescores ONLY those candidates against the exact f32 store and
//! emits the final top-k: full-precision work becomes sublinear in corpus
//! size while the linear pass runs on the cheap codec. This is the
//! reranker substrate any future ANN index will sit on — the coarse scan
//! is the recall stage, the exact rescore the precision stage.
//!
//! Stage 1 fans out per shard either on per-query scoped threads (the same
//! scatter/gather path as [`ParallelQueryEngine`](super::ParallelQueryEngine))
//! or on a persistent [`ScanPool`](super::ScanPool) attached via
//! [`BackendConfig::pool`](super::BackendConfig), where concurrent queries
//! interleave their shard tasks on warm workers. Per-shard pools merge with [`TopK`]'s total
//! order, so the candidate pool — and therefore the final result — is
//! deterministic for any shard decomposition, worker count, and
//! interleaving. Stage-2 scores are computed with the same f32 dot
//! accumulation order and f64 RelatIF division as the sequential
//! [`QueryEngine`](super::QueryEngine) native scan, so whenever the pool
//! covers the whole corpus (`rescore_factor × topk ≥ rows`) the output is
//! **bit-identical** to the exact engine (verified by
//! `rust/tests/twostage.rs` and `rust/tests/pool.rs`); smaller pools trade
//! bounded recall for bandwidth.
//!
//! The engine needs BOTH stores (shared ownership via `Arc`): the
//! quantized copy (produced by `logra store quantize`) for stage 1 and the
//! original f32 store for stage 2. `quantize_store` preserves global row
//! order and ids, which is what lets stage-1 candidates (global row
//! indices) address the exact store directly.

use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::coordinator::metrics::Metrics;
use crate::hessian::Preconditioner;
use crate::linalg::kernels::{auto_chunk_len, dot_f32, scan_q8_into};
use crate::linalg::ScanScratch;
use crate::obs::{QueryReport, ScanObs};
use crate::store::quant::{blocks_of, quantize_rows, QuantShardedStore};
use crate::store::ShardedStore;
use crate::util::topk::TopK;

use super::backend::{
    BackendConfig, BackendKind, GradQuery, PendingScores, QueryRequest, ReportCtx,
    ScanBackend, ValuationError,
};
use super::parallel::{
    cached_self_influences, resolve_chunk_len_self_inf, resolve_workers, scatter_gather,
};
use super::pool::{ScanHandle, NEVER_POLL};
use super::scorer::{Normalization, QueryResult};

/// Two-stage influence scorer: quantized coarse scan + exact rescore.
/// `Send + Sync` — share behind an `Arc` and query concurrently.
pub struct TwoStageEngine {
    quant: Arc<QuantShardedStore>,
    exact: Arc<ShardedStore>,
    precond: Arc<Preconditioner>,
    cfg: BackendConfig,
    /// Self-influence per GLOBAL row (RelatIF denominators), computed from
    /// the EXACT store — both stages divide by the same denominators.
    self_inf: Mutex<Option<Arc<Vec<f32>>>>,
}

impl TwoStageEngine {
    /// The quantized copy must mirror the exact store row-for-row (use
    /// `quantize_store`, which preserves global order and ids). Rejects a
    /// stale or mismatched pairing — and a zero `rescore_factor` — with a
    /// typed [`ValuationError`] at construction.
    pub fn new(
        quant: Arc<QuantShardedStore>,
        exact: Arc<ShardedStore>,
        precond: Arc<Preconditioner>,
        cfg: BackendConfig,
    ) -> Result<Self, ValuationError> {
        if quant.k() != exact.k() {
            return Err(ValuationError::InvalidConfig(format!(
                "quantized store k={} disagrees with exact store k={}",
                quant.k(),
                exact.k()
            )));
        }
        if quant.rows() != exact.rows() {
            return Err(ValuationError::InvalidConfig(format!(
                "quantized store has {} rows, exact store {} — stale quantized copy?",
                quant.rows(),
                exact.rows()
            )));
        }
        if cfg.rescore_factor == 0 {
            return Err(ValuationError::InvalidConfig(
                "rescore_factor must be ≥ 1 (stage-1 candidate pool multiplier)".into(),
            ));
        }
        Ok(TwoStageEngine { quant, exact, precond, cfg, self_inf: Mutex::new(None) })
    }

    /// Stage-1 candidate pool size for a requested top-k.
    pub fn pool_size(&self, topk: usize) -> usize {
        self.cfg
            .rescore_factor
            .max(1)
            .saturating_mul(topk.max(1))
            .min(self.exact.rows().max(1))
    }

    /// Self-influence of each stored row in global order, from the exact
    /// store (computed once in parallel, then cached; concurrent callers
    /// block on the first computation and share the result).
    pub fn train_self_influences(&self) -> Arc<Vec<f32>> {
        cached_self_influences(
            &self.self_inf,
            &self.exact,
            &self.precond,
            resolve_workers(self.cfg.workers, self.exact.n_shards()),
            resolve_chunk_len_self_inf(self.cfg.chunk_len, self.exact.k()),
        )
    }

    /// Admission body behind [`ScanBackend::submit`]: run (or enqueue) the
    /// stage-1 coarse scan; the returned handle's `wait` merges candidate
    /// pools and performs the exact rescore on the calling thread.
    fn submit_grads(&self, q: GradQuery) -> Result<PendingScores, ValuationError> {
        let GradQuery { rows: test_grads, nt, topk, norm } = q;
        let k = self.exact.k();
        let scan_obs = self.cfg.metrics.as_ref().map(|m| Arc::new(ScanObs::new(&m.obs)));
        let pre = self.precond.apply_rows(&test_grads, nt);
        let selfs: Option<Arc<Vec<f32>>> = match norm {
            Normalization::RelatIf => Some(self.train_self_influences()),
            Normalization::None => None,
        };
        let pool_size = self.pool_size(topk);
        let ctx = match (&self.cfg.metrics, &scan_obs) {
            (Some(m), Some(so)) => Some(ReportCtx::new(
                m.clone(),
                so.clone(),
                BackendKind::TwoStage.name(),
                self.quant.n_shards() as u32,
                self.quant.rows() as u64,
            )),
            _ => None,
        };
        let t0 = Instant::now();

        // ------------------------------------------------ stage 1: coarse
        // Quantize the preconditioned test rows with the store's codec so
        // the scan is int8 x int8 with i32 block accumulation.
        let scan = if self.exact.rows() == 0 {
            ScanHandle::Ready(Vec::new())
        } else {
            let (t_codes, t_scales) = quantize_rows(&pre, nt, k);
            // Auto chunks size to the int8 row footprint (codes + scales).
            let q8_row_bytes = k + blocks_of(k) * 4;
            let chunk_len = if self.cfg.chunk_len != 0 {
                self.cfg.chunk_len
            } else {
                auto_chunk_len(k, nt, q8_row_bytes)
            };
            if let Some(m) = &self.cfg.metrics {
                m.scan_chunk_len.store(chunk_len as u64, std::sync::atomic::Ordering::Relaxed);
            }
            match &self.cfg.pool {
                Some(pool) => {
                    let quant = self.quant.clone();
                    let metrics = self.cfg.metrics.clone();
                    let selfs = selfs.clone();
                    let scan_obs = scan_obs.clone();
                    let t_codes = Arc::new(t_codes);
                    let t_scales = Arc::new(t_scales);
                    ScanHandle::Pool(pool.submit_with_scratch(
                        self.quant.n_shards(),
                        move |si, scratch| {
                            scan_shard_q8(
                                &quant,
                                si,
                                &t_codes,
                                &t_scales,
                                nt,
                                pool_size,
                                selfs.as_ref().map(|s| s.as_slice()),
                                chunk_len,
                                metrics.as_deref(),
                                scan_obs.as_deref(),
                                scratch,
                            )
                        },
                    )?)
                }
                None => {
                    let quant = &self.quant;
                    let met = self.cfg.metrics.as_deref();
                    let so_ref = scan_obs.as_deref();
                    let tc: &[i8] = &t_codes;
                    let ts: &[f32] = &t_scales;
                    let selfs_ref: Option<&[f32]> = selfs.as_ref().map(|s| s.as_slice());
                    ScanHandle::Ready(scatter_gather(
                        self.workers(),
                        quant.n_shards(),
                        &|si, scratch| {
                            scan_shard_q8(
                                quant,
                                si,
                                tc,
                                ts,
                                nt,
                                pool_size,
                                selfs_ref,
                                chunk_len,
                                met,
                                so_ref,
                                scratch,
                            )
                        },
                    ))
                }
            }
        };
        Ok(PendingScores::rescore(PendingRescore {
            scan,
            pre,
            selfs,
            exact: self.exact.clone(),
            metrics: self.cfg.metrics.clone(),
            nt,
            topk,
            pool_size,
            t0,
            ctx,
        }))
    }
}

impl ScanBackend for TwoStageEngine {
    fn submit(&self, req: QueryRequest) -> Result<PendingScores, ValuationError> {
        self.submit_grads(req.resolve(self.cfg.norm, self.exact.k())?)
    }

    fn kind(&self) -> BackendKind {
        BackendKind::TwoStage
    }

    fn rows(&self) -> usize {
        self.exact.rows()
    }

    fn k(&self) -> usize {
        self.exact.k()
    }

    /// Resolved stage-1 worker count (the pool's when attached).
    fn workers(&self) -> usize {
        match &self.cfg.pool {
            Some(pool) => pool.workers(),
            None => resolve_workers(self.cfg.workers, self.quant.n_shards()),
        }
    }

    /// Approximate: exactness depends on the rescore pool covering the
    /// corpus (`rescore_factor × topk ≥ rows`), a per-request property.
    fn exact(&self) -> bool {
        false
    }

    fn gradient_row(&self, i: usize) -> Option<Vec<f32>> {
        (i < self.exact.rows()).then(|| self.exact.row(i).to_vec())
    }
}

/// An admitted two-stage query: stage-1 shard pools in flight (or ready).
/// `finish` merges them deterministically and runs the exact stage-2
/// rescore on the calling thread — same math, same order, same results as
/// the synchronous path. Callers hold this inside the shared
/// [`PendingScores`] handle.
pub(crate) struct PendingRescore {
    scan: ScanHandle,
    /// Preconditioned test rows [nt, k] — stage 2 rescores against these.
    pre: Vec<f32>,
    selfs: Option<Arc<Vec<f32>>>,
    exact: Arc<ShardedStore>,
    metrics: Option<Arc<Metrics>>,
    nt: usize,
    topk: usize,
    pool_size: usize,
    /// Stage-1 wall clock starts at admission (includes pool queue wait).
    t0: Instant,
    /// Per-query report builder — present when metrics are attached.
    ctx: Option<ReportCtx>,
}

impl PendingRescore {
    /// Assemble a pending rescore from a different stage-1 implementation
    /// (the IVF engine's probed scan in [`super::ann`]) — the merge +
    /// exact-rescore stage 2 is shared verbatim, which is what makes the
    /// full-probe IVF path bit-identical to this engine.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn new(
        scan: ScanHandle,
        pre: Vec<f32>,
        selfs: Option<Arc<Vec<f32>>>,
        exact: Arc<ShardedStore>,
        metrics: Option<Arc<Metrics>>,
        nt: usize,
        topk: usize,
        pool_size: usize,
        t0: Instant,
        ctx: Option<ReportCtx>,
    ) -> Self {
        PendingRescore { scan, pre, selfs, exact, metrics, nt, topk, pool_size, t0, ctx }
    }

    pub(crate) fn finish(
        self,
    ) -> Result<(Vec<QueryResult>, Option<QueryReport>), ValuationError> {
        self.finish_until(&mut || false, NEVER_POLL)
    }

    /// [`finish`](Self::finish) with a cancellation seam: `should_cancel`
    /// is re-checked every `poll` interval while the stage-1 scan is in
    /// flight, and once more before starting the stage-2 rescore (the
    /// rescore runs on the calling thread, so a deadline that expired
    /// during stage 1 should not buy a full rescore it will discard).
    pub(crate) fn finish_until(
        self,
        should_cancel: &mut dyn FnMut() -> bool,
        poll: std::time::Duration,
    ) -> Result<(Vec<QueryResult>, Option<QueryReport>), ValuationError> {
        let k = self.exact.k();
        let query_id = match &self.scan {
            ScanHandle::Pool(p) => p.query_id(),
            ScanHandle::Ready(_) => 0,
        };
        let shard_pools = self.scan.wait_until(should_cancel, poll)?;
        if should_cancel() {
            return Err(ValuationError::Cancelled { query_id });
        }
        let scan_done = self.ctx.as_ref().map(|c| c.scan.elapsed_nanos()).unwrap_or(0);
        let mut pools: Vec<TopK> = (0..self.nt).map(|_| TopK::new(self.pool_size)).collect();
        for heaps in shard_pools {
            for (t, h) in heaps.into_iter().enumerate() {
                pools[t].merge(h);
            }
        }
        let metrics = self.metrics.as_deref();
        if let Some(m) = metrics {
            Metrics::add_seconds(&m.stage1_nanos, self.t0.elapsed().as_secs_f64());
        }
        let selfs: Option<&[f32]> = self.selfs.as_ref().map(|s| s.as_slice());

        // ---------------------------------------------- stage 2: rescore
        // Exact f32 dots for pool candidates only — same accumulation order
        // and f64 normalization as the sequential engine, so a full-corpus
        // pool reproduces it bit-identically.
        let rescore_start = self.ctx.as_ref().map(|c| c.scan.elapsed_nanos()).unwrap_or(0);
        let t1 = Instant::now();
        let mut rescored = 0u64;
        let mut out = Vec::with_capacity(self.nt);
        for (t, p) in pools.into_iter().enumerate() {
            let pre_t = &self.pre[t * k..(t + 1) * k];
            let mut cand: Vec<u64> = p.into_sorted().into_iter().map(|(_, g)| g).collect();
            // Ascending row order: sequential-ish page access into the mmap.
            cand.sort_unstable();
            let mut heap = TopK::new(self.topk.max(1));
            for g in cand {
                let g = g as usize;
                // Kernel dot: the same per-pair summation discipline as
                // the sequential scan's chunk kernel, which is what keeps
                // full-coverage pools bit-identical to the exact engine.
                let s = dot_f32(pre_t, self.exact.row(g)) as f64;
                let s = match selfs {
                    Some(si) => s / (si[g].max(0.0) as f64).sqrt().max(1e-12),
                    None => s,
                };
                heap.push(s, self.exact.id(g));
                rescored += 1;
            }
            out.push(QueryResult { top: heap.into_sorted() });
        }
        if let Some(m) = metrics {
            Metrics::add_seconds(&m.stage2_nanos, t1.elapsed().as_secs_f64());
            m.candidates_rescored.fetch_add(rescored, std::sync::atomic::Ordering::Relaxed);
        }
        let report = self.ctx.map(|c| c.complete(scan_done, rescore_start, rescored));
        Ok((out, report))
    }
}

/// Stage-1 scan of one quantized shard: per-test-row candidate pools of
/// (approximate score, GLOBAL row index). `scratch` holds the score
/// buffer between chunks — no per-chunk allocation.
#[allow(clippy::too_many_arguments)]
fn scan_shard_q8(
    quant: &QuantShardedStore,
    si: usize,
    t_codes: &[i8],
    t_scales: &[f32],
    nt: usize,
    pool: usize,
    selfs: Option<&[f32]>,
    chunk_len: usize,
    metrics: Option<&Metrics>,
    scan_obs: Option<&ScanObs>,
    scratch: &mut ScanScratch,
) -> Vec<TopK> {
    let obs_start = metrics.map(|m| m.obs.now_nanos());
    if let (Some(m), Some(so)) = (metrics, scan_obs) {
        so.task_started(&m.obs);
    }
    let t0 = Instant::now();
    let k = quant.k();
    let shard = quant.shard(si);
    let base = quant.shard_start(si);
    let mut heaps: Vec<TopK> = (0..nt).map(|_| TopK::new(pool)).collect();
    let rows = shard.rows();
    let mut at = 0usize;
    while at < rows {
        let len = chunk_len.min(rows - at);
        if at + len < rows {
            shard.prefetch(at + len, chunk_len.min(rows - at - len));
        }
        let scores = scratch.score_buf(nt * len);
        scan_q8_into(
            t_codes,
            t_scales,
            nt,
            shard.codes_chunk(at, len),
            shard.scales_chunk(at, len),
            len,
            k,
            scores,
        );
        for (t, heap) in heaps.iter_mut().enumerate() {
            let srow = &scores[t * len..(t + 1) * len];
            for (j, &s) in srow.iter().enumerate() {
                let g = base + at + j;
                // Same RelatIF denominators as stage 2, so the pool chases
                // the ranking the rescore will finalize.
                let s = match selfs {
                    Some(si_all) => {
                        s as f64 / (si_all[g].max(0.0) as f64).sqrt().max(1e-12)
                    }
                    None => s as f64,
                };
                heap.push(s, g as u64);
            }
        }
        at += len;
    }
    if let Some(m) = metrics {
        m.shards_scanned.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let dur = t0.elapsed();
        Metrics::add_seconds(&m.shard_scan_nanos, dur.as_secs_f64());
        let dur_nanos = dur.as_nanos() as u64;
        m.obs.shard_scan.record(dur_nanos);
        m.obs.span(
            "scan",
            scan_obs.map(|s| s.query()).unwrap_or(0),
            Some(si as u32),
            obs_start.unwrap_or(0),
            dur_nanos,
        );
    }
    heaps
}
