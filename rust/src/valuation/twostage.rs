//! Two-stage scan-then-rescore query engine over a quantized store.
//!
//! Stage 1 scans the int8 quantized copy of the corpus
//! ([`QuantShardedStore`]) with the i32-accumulating block-dot kernel —
//! 4x less memory bandwidth than the f32 scan — and keeps, per test row, a
//! candidate pool of `rescore_factor × topk` rows by approximate score.
//! Stage 2 rescores ONLY those candidates against the exact f32 store and
//! emits the final top-k: full-precision work becomes sublinear in corpus
//! size while the linear pass runs on the cheap codec. This is the
//! reranker substrate any future ANN index will sit on — the coarse scan
//! is the recall stage, the exact rescore the precision stage.
//!
//! Stage 1 fans out per shard through the same scatter/gather worker pool
//! as [`ParallelQueryEngine`](super::ParallelQueryEngine) and merges
//! per-shard pools with [`TopK`]'s total order, so the candidate pool — and
//! therefore the final result — is deterministic for any shard
//! decomposition and worker count. Stage-2 scores are computed with the
//! same f32 dot accumulation order and f64 RelatIF division as the
//! sequential [`QueryEngine`](super::QueryEngine) native scan, so whenever
//! the pool covers the whole corpus (`rescore_factor × topk ≥ rows`) the
//! output is **bit-identical** to the exact engine (verified by
//! `rust/tests/twostage.rs`); smaller pools trade bounded recall for
//! bandwidth.
//!
//! The engine needs BOTH stores: the quantized copy (produced by
//! `logra store quantize`) for stage 1 and the original f32 store for
//! stage 2. `quantize_store` preserves global row order and ids, which is
//! what lets stage-1 candidates (global row indices) address the exact
//! store directly.

use std::cell::{Ref, RefCell};
use std::sync::Arc;
use std::time::Instant;

use anyhow::{ensure, Result};

use crate::coordinator::metrics::Metrics;
use crate::hessian::Preconditioner;
use crate::linalg::dot;
use crate::store::quant::{quantize_rows, scan_scores_q8, QuantShardedStore};
use crate::store::ShardedStore;
use crate::util::topk::TopK;

use super::parallel::{resolve_workers, scatter_gather, shard_self_influences};
use super::scorer::{Normalization, QueryResult};

/// Knobs for the two-stage scan.
#[derive(Clone, Copy, Debug)]
pub struct TwoStageConfig {
    /// Worker threads for the stage-1 shard fan-out; 0 = one per core.
    pub workers: usize,
    /// Rows scored per chunk within a shard.
    pub chunk_len: usize,
    /// Stage-1 candidate pool per test row, as a multiple of the requested
    /// top-k (clamped to at least 1; pools never exceed the corpus).
    pub rescore_factor: usize,
}

impl Default for TwoStageConfig {
    fn default() -> Self {
        TwoStageConfig { workers: 0, chunk_len: 1024, rescore_factor: 4 }
    }
}

/// Two-stage influence scorer: quantized coarse scan + exact rescore.
pub struct TwoStageEngine<'a> {
    quant: &'a QuantShardedStore,
    exact: &'a ShardedStore,
    precond: &'a Preconditioner,
    cfg: TwoStageConfig,
    metrics: Option<Arc<Metrics>>,
    /// Self-influence per GLOBAL row (RelatIF denominators), computed from
    /// the EXACT store — both stages divide by the same denominators.
    self_inf: RefCell<Option<Vec<f32>>>,
}

impl<'a> TwoStageEngine<'a> {
    /// The quantized copy must mirror the exact store row-for-row (use
    /// `quantize_store`, which preserves global order and ids).
    pub fn new(
        quant: &'a QuantShardedStore,
        exact: &'a ShardedStore,
        precond: &'a Preconditioner,
    ) -> Result<Self> {
        ensure!(
            quant.k() == exact.k(),
            "quantized store k={} disagrees with exact store k={}",
            quant.k(),
            exact.k()
        );
        ensure!(
            quant.rows() == exact.rows(),
            "quantized store has {} rows, exact store {} — stale quantized copy?",
            quant.rows(),
            exact.rows()
        );
        Ok(TwoStageEngine {
            quant,
            exact,
            precond,
            cfg: TwoStageConfig::default(),
            metrics: None,
            self_inf: RefCell::new(None),
        })
    }

    /// Set worker count (0 = auto).
    pub fn with_workers(mut self, workers: usize) -> Self {
        self.cfg.workers = workers;
        self
    }

    pub fn with_chunk_len(mut self, chunk_len: usize) -> Self {
        self.cfg.chunk_len = chunk_len.max(1);
        self
    }

    pub fn with_rescore_factor(mut self, factor: usize) -> Self {
        self.cfg.rescore_factor = factor.max(1);
        self
    }

    /// Record stage timings and candidate counts into shared metrics.
    pub fn with_metrics(mut self, metrics: Arc<Metrics>) -> Self {
        self.metrics = Some(metrics);
        self
    }

    /// Resolved stage-1 worker count.
    pub fn workers(&self) -> usize {
        resolve_workers(self.cfg.workers, self.quant.n_shards())
    }

    /// Stage-1 candidate pool size for a requested top-k.
    pub fn pool_size(&self, topk: usize) -> usize {
        self.cfg
            .rescore_factor
            .max(1)
            .saturating_mul(topk.max(1))
            .min(self.exact.rows().max(1))
    }

    /// Self-influence of each stored row in global order, from the exact
    /// store (computed once in parallel, then cached).
    pub fn train_self_influences(&self) -> Ref<'_, [f32]> {
        if self.self_inf.borrow().is_none() {
            let store = self.exact;
            let precond = self.precond;
            let chunk_len = self.cfg.chunk_len.max(1);
            let workers = resolve_workers(self.cfg.workers, store.n_shards());
            let per_shard = scatter_gather(workers, store.n_shards(), &|si| {
                shard_self_influences(store, precond, si, chunk_len)
            });
            let mut flat = Vec::with_capacity(store.rows());
            for v in per_shard {
                flat.extend(v);
            }
            *self.self_inf.borrow_mut() = Some(flat);
        }
        Ref::map(self.self_inf.borrow(), |o| o.as_deref().unwrap())
    }

    /// Top-k most valuable train examples per test row. Same contract as
    /// [`QueryEngine::query`](super::QueryEngine::query): `test_grads` is
    /// row-major [nt, k] of RAW projected test gradients.
    pub fn query(
        &self,
        test_grads: &[f32],
        nt: usize,
        topk: usize,
        norm: Normalization,
    ) -> Result<Vec<QueryResult>> {
        let k = self.exact.k();
        ensure!(
            test_grads.len() == nt * k,
            "query: {nt} rows x k={k} needs {} floats, got {}",
            nt * k,
            test_grads.len()
        );
        let pre = self.precond.apply_rows(test_grads, nt);
        let selfs_guard = match norm {
            Normalization::RelatIf => Some(self.train_self_influences()),
            Normalization::None => None,
        };
        let selfs: Option<&[f32]> = selfs_guard.as_deref();
        let rows = self.exact.rows();
        if rows == 0 {
            return Ok((0..nt).map(|_| QueryResult { top: Vec::new() }).collect());
        }
        let pool = self.pool_size(topk);

        // ------------------------------------------------ stage 1: coarse
        // Quantize the preconditioned test rows with the store's codec so
        // the scan is int8 x int8 with i32 block accumulation.
        let t0 = Instant::now();
        let (t_codes, t_scales) = quantize_rows(&pre, nt, k);
        let quant = self.quant;
        let chunk_len = self.cfg.chunk_len.max(1);
        let metrics = self.metrics.as_deref();
        let tc: &[i8] = &t_codes;
        let ts: &[f32] = &t_scales;
        let shard_pools = scatter_gather(self.workers(), quant.n_shards(), &|si| {
            scan_shard_q8(quant, si, tc, ts, nt, pool, selfs, chunk_len, metrics)
        });
        let mut pools: Vec<TopK> = (0..nt).map(|_| TopK::new(pool)).collect();
        for heaps in shard_pools {
            for (t, h) in heaps.into_iter().enumerate() {
                pools[t].merge(h);
            }
        }
        if let Some(m) = metrics {
            Metrics::add_nanos(&m.stage1_nanos, t0.elapsed().as_secs_f64());
        }

        // ---------------------------------------------- stage 2: rescore
        // Exact f32 dots for pool candidates only — same accumulation order
        // and f64 normalization as the sequential engine, so a full-corpus
        // pool reproduces it bit-identically.
        let t1 = Instant::now();
        let mut rescored = 0u64;
        let mut out = Vec::with_capacity(nt);
        for (t, p) in pools.into_iter().enumerate() {
            let pre_t = &pre[t * k..(t + 1) * k];
            let mut cand: Vec<u64> = p.into_sorted().into_iter().map(|(_, g)| g).collect();
            // Ascending row order: sequential-ish page access into the mmap.
            cand.sort_unstable();
            let mut heap = TopK::new(topk.max(1));
            for g in cand {
                let g = g as usize;
                let s = dot(pre_t, self.exact.row(g)) as f64;
                let s = match selfs {
                    Some(si) => s / (si[g].max(0.0) as f64).sqrt().max(1e-12),
                    None => s,
                };
                heap.push(s, self.exact.id(g));
                rescored += 1;
            }
            out.push(QueryResult { top: heap.into_sorted() });
        }
        if let Some(m) = metrics {
            Metrics::add_nanos(&m.stage2_nanos, t1.elapsed().as_secs_f64());
            m.candidates_rescored.fetch_add(rescored, std::sync::atomic::Ordering::Relaxed);
        }
        Ok(out)
    }
}

/// Stage-1 scan of one quantized shard: per-test-row candidate pools of
/// (approximate score, GLOBAL row index).
#[allow(clippy::too_many_arguments)]
fn scan_shard_q8(
    quant: &QuantShardedStore,
    si: usize,
    t_codes: &[i8],
    t_scales: &[f32],
    nt: usize,
    pool: usize,
    selfs: Option<&[f32]>,
    chunk_len: usize,
    metrics: Option<&Metrics>,
) -> Vec<TopK> {
    let t0 = Instant::now();
    let k = quant.k();
    let shard = quant.shard(si);
    let base = quant.shard_start(si);
    let mut heaps: Vec<TopK> = (0..nt).map(|_| TopK::new(pool)).collect();
    let rows = shard.rows();
    let mut at = 0usize;
    while at < rows {
        let len = chunk_len.min(rows - at);
        if at + len < rows {
            shard.prefetch(at + len, chunk_len.min(rows - at - len));
        }
        let scores = scan_scores_q8(
            t_codes,
            t_scales,
            nt,
            shard.codes_chunk(at, len),
            shard.scales_chunk(at, len),
            len,
            k,
        );
        for (t, heap) in heaps.iter_mut().enumerate() {
            let srow = &scores[t * len..(t + 1) * len];
            for (j, &s) in srow.iter().enumerate() {
                let g = base + at + j;
                // Same RelatIF denominators as stage 2, so the pool chases
                // the ranking the rescore will finalize.
                let s = match selfs {
                    Some(si_all) => {
                        s as f64 / (si_all[g].max(0.0) as f64).sqrt().max(1e-12)
                    }
                    None => s as f64,
                };
                heap.push(s, g as u64);
            }
        }
        at += len;
    }
    if let Some(m) = metrics {
        m.shards_scanned.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        Metrics::add_nanos(&m.shard_scan_nanos, t0.elapsed().as_secs_f64());
    }
    heaps
}
