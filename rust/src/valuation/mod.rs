//! Influence-scoring engine: iHVP-preconditioned dot products over the
//! gradient store, ℓ-RelatIF normalization, top-k selection.
//!
//! This is the paper's recurring "Compute Influence" phase (Table 1,
//! right): test gradients are preconditioned once, then scanned against
//! every stored train gradient; the scan is chunked, each chunk's scores
//! come from the Pallas-authored `score` HLO program (or a native fallback
//! for odd shapes), and the next chunk is prefetched while the current one
//! is scored. Over sharded stores, [`parallel::ParallelQueryEngine`] fans
//! the scan out across worker threads and merges per-shard top-k heaps
//! deterministically. Over quantized stores, [`twostage::TwoStageEngine`]
//! runs the linear pass on the int8 codec and rescores only a small
//! candidate pool at exact precision. Under serving load, both engines
//! attach to a persistent [`pool::ScanPool`], which admits concurrent
//! queries, interleaves their shard tasks across warm workers, and keeps
//! results bit-identical to the sequential scan.

pub mod parallel;
pub mod pool;
pub mod scorer;
pub mod twostage;

pub use parallel::{ParallelQueryEngine, ParallelScanConfig, PendingQuery};
pub use pool::{auto_workers, PendingScan, PoolSnapshot, ScanHandle, ScanPool};
pub use scorer::{Normalization, QueryEngine, QueryResult};
pub use twostage::{PendingTwoStage, TwoStageConfig, TwoStageEngine};
