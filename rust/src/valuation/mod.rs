//! Influence-scoring engine: iHVP-preconditioned dot products over the
//! gradient store, ℓ-RelatIF normalization, top-k selection.
//!
//! This is the paper's recurring "Compute Influence" phase (Table 1,
//! right): test gradients are preconditioned once, then scanned against
//! every stored train gradient. The public seam is the [`ScanBackend`]
//! trait plus the [`Valuator`] session facade ([`backend`]):
//! `Valuator::open(dir)` opens the store fabric once, auto-detects the
//! codec, and serves `query` / `query_async` / `query_batch` through ONE
//! [`PendingScores`] completion handle, with typed [`ValuationError`]s.
//!
//! Four backends implement the trait: [`SequentialEngine`] (one thread,
//! the unsharded shape), [`parallel::ParallelQueryEngine`] (per-shard
//! fan-out, deterministic merge), [`twostage::TwoStageEngine`] (int8
//! coarse scan + exact rescore of a small candidate pool), and
//! [`ann::IvfEngine`] (IVF stage-0 probe pruning the coarse scan to the
//! `nprobe` nearest clusters per shard). All four are bit-identical to
//! the sequential [`QueryEngine`] native scan whenever exactness applies
//! (`rust/tests/backend.rs`). A [`Valuator`] builds every engine its
//! fabric can serve and routes per request via
//! [`QueryRequest::backend`](backend::BackendChoice). Under serving load the
//! fan-out backends attach to a persistent [`pool::ScanPool`], which
//! admits concurrent queries and interleaves their shard tasks across
//! warm workers. [`scorer::QueryEngine`] remains the borrow-based
//! reference engine (and the only one that can score through the AOT HLO
//! `score` program).
//!
//! With [`BackendConfig::metrics`] attached, every backend also records
//! per-query trace spans and latency histograms ([`crate::obs`]) and can
//! return a [`crate::obs::QueryReport`] stage breakdown via
//! `query_with_report` / [`PendingScores::wait_with_report`].

pub mod ann;
pub mod backend;
pub mod parallel;
pub mod pool;
pub mod scorer;
pub mod twostage;

pub use ann::IvfEngine;
pub use backend::{
    Backend, BackendChoice, BackendConfig, BackendKind, PendingScores, PoolMode,
    QuarantinedShard, QueryInput, QueryRequest, ScanBackend, SequentialEngine, ValuationError,
    Valuator, ValuatorBuilder,
};
pub use parallel::ParallelQueryEngine;
pub use pool::{auto_workers, PendingScan, PoolSnapshot, ScanHandle, ScanPool};
pub use scorer::{Normalization, QueryEngine, QueryResult};
pub use twostage::TwoStageEngine;
