//! TOML-subset configuration parser + typed configs.
//!
//! Parses exactly the subset `configs/*.toml` uses (and `python/compile/
//! config.py` mirrors): `[section]` headers, `key = value` with string /
//! int / float / bool / flat int-list values, `#` comments. Hand-rolled
//! because no serde/toml crate exists offline (DESIGN.md §1).

use std::collections::BTreeMap;
use std::fmt;
use std::path::Path;

#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    IntList(Vec<i64>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_int(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_float(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_int_list(&self) -> Option<&[i64]> {
        match self {
            Value::IntList(v) => Some(v),
            _ => None,
        }
    }
}

#[derive(Debug)]
pub struct ParseError {
    pub line: usize,
    pub message: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "config parse error at line {}: {}", self.line, self.message)
    }
}

impl std::error::Error for ParseError {}

/// Parsed document: section -> key -> value.
#[derive(Clone, Debug, Default)]
pub struct Document {
    pub sections: BTreeMap<String, BTreeMap<String, Value>>,
}

impl Document {
    pub fn get(&self, section: &str, key: &str) -> Option<&Value> {
        self.sections.get(section)?.get(key)
    }

    pub fn str_of(&self, section: &str, key: &str) -> anyhow::Result<String> {
        self.get(section, key)
            .and_then(|v| v.as_str())
            .map(str::to_string)
            .ok_or_else(|| anyhow::anyhow!("missing string [{section}].{key}"))
    }

    pub fn int_of(&self, section: &str, key: &str) -> anyhow::Result<i64> {
        self.get(section, key)
            .and_then(|v| v.as_int())
            .ok_or_else(|| anyhow::anyhow!("missing int [{section}].{key}"))
    }

    pub fn float_of(&self, section: &str, key: &str) -> anyhow::Result<f64> {
        self.get(section, key)
            .and_then(|v| v.as_float())
            .ok_or_else(|| anyhow::anyhow!("missing float [{section}].{key}"))
    }

    pub fn float_or(&self, section: &str, key: &str, default: f64) -> f64 {
        self.get(section, key).and_then(|v| v.as_float()).unwrap_or(default)
    }

    pub fn str_or(&self, section: &str, key: &str, default: &str) -> String {
        self.get(section, key)
            .and_then(|v| v.as_str())
            .map(str::to_string)
            .unwrap_or_else(|| default.to_string())
    }
}

fn parse_scalar(raw: &str, line_no: usize) -> Result<Value, ParseError> {
    let raw = raw.trim();
    let err = |m: &str| ParseError { line: line_no, message: m.to_string() };
    if raw.is_empty() {
        return Err(err("empty value"));
    }
    if raw.starts_with('"') {
        if raw.len() < 2 || !raw.ends_with('"') {
            return Err(err("unterminated string"));
        }
        return Ok(Value::Str(raw[1..raw.len() - 1].to_string()));
    }
    if raw == "true" {
        return Ok(Value::Bool(true));
    }
    if raw == "false" {
        return Ok(Value::Bool(false));
    }
    if raw.starts_with('[') {
        if !raw.ends_with(']') {
            return Err(err("unterminated list"));
        }
        let inner = &raw[1..raw.len() - 1];
        let mut items = Vec::new();
        for part in inner.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            items.push(
                part.parse::<i64>()
                    .map_err(|_| err(&format!("bad int list item {part:?}")))?,
            );
        }
        return Ok(Value::IntList(items));
    }
    if let Ok(i) = raw.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = raw.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(err(&format!("unrecognized value {raw:?}")))
}

/// Strip a trailing comment that is not inside a string literal.
fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, ch) in line.char_indices() {
        match ch {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

pub fn parse(text: &str) -> Result<Document, ParseError> {
    let mut doc = Document::default();
    let mut section = String::new();
    for (idx, raw_line) in text.lines().enumerate() {
        let line_no = idx + 1;
        let line = strip_comment(raw_line).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let name = rest.strip_suffix(']').ok_or(ParseError {
                line: line_no,
                message: "unterminated section header".into(),
            })?;
            section = name.trim().to_string();
            doc.sections.entry(section.clone()).or_default();
            continue;
        }
        let (key, value) = line.split_once('=').ok_or(ParseError {
            line: line_no,
            message: format!("expected `key = value`, got {line:?}"),
        })?;
        if section.is_empty() {
            return Err(ParseError {
                line: line_no,
                message: "key outside any [section]".into(),
            });
        }
        let v = parse_scalar(value, line_no)?;
        doc.sections
            .get_mut(&section)
            .unwrap()
            .insert(key.trim().to_string(), v);
    }
    Ok(doc)
}

pub fn parse_file(path: &Path) -> anyhow::Result<Document> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("read {}: {e}", path.display()))?;
    Ok(parse(&text)?)
}

// ------------------------------------------------------------ typed view

/// Typed experiment config (mirror of python `compile.config.Config`).
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    pub name: String,
    pub kind: String, // "lm" | "mlp"
    pub doc: Document,
}

impl ExperimentConfig {
    pub fn load(path: &Path) -> anyhow::Result<Self> {
        let doc = parse_file(path)?;
        Ok(ExperimentConfig {
            name: doc.str_of("meta", "name")?,
            kind: doc.str_of("meta", "kind")?,
            doc,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# top comment
[meta]
name = "lm_tiny"    # inline comment
kind = "lm"

[model]
vocab = 256
lr = 1e-3
hidden = [128, 128]
flag = true
"#;

    #[test]
    fn parses_sample() {
        let doc = parse(SAMPLE).unwrap();
        assert_eq!(doc.str_of("meta", "name").unwrap(), "lm_tiny");
        assert_eq!(doc.int_of("model", "vocab").unwrap(), 256);
        assert!((doc.float_of("model", "lr").unwrap() - 1e-3).abs() < 1e-12);
        assert_eq!(
            doc.get("model", "hidden").unwrap().as_int_list().unwrap(),
            &[128, 128]
        );
        assert_eq!(doc.get("model", "flag").unwrap(), &Value::Bool(true));
    }

    #[test]
    fn hash_inside_string_kept() {
        let doc = parse("[a]\nk = \"x # y\"\n").unwrap();
        assert_eq!(doc.str_of("a", "k").unwrap(), "x # y");
    }

    #[test]
    fn errors_have_line_numbers() {
        let err = parse("[a]\nk == 3\n").unwrap_err();
        assert_eq!(err.line, 2);
        let err = parse("k = 3\n").unwrap_err();
        assert_eq!(err.line, 1);
        let err = parse("[a\n").unwrap_err();
        assert_eq!(err.line, 1);
    }

    #[test]
    fn int_promotes_to_float() {
        let doc = parse("[a]\nk = 3\n").unwrap();
        assert_eq!(doc.float_of("a", "k").unwrap(), 3.0);
    }

    #[test]
    fn real_configs_parse() {
        for name in ["lm_tiny", "lm_small", "mlp_fmnist", "mlp_cifar", "lm_wikitext"] {
            let path = format!("{}/configs/{name}.toml", env!("CARGO_MANIFEST_DIR"));
            let cfg = ExperimentConfig::load(Path::new(&path)).unwrap();
            assert_eq!(cfg.name, name);
            assert!(cfg.kind == "lm" || cfg.kind == "mlp");
            assert!(cfg.doc.int_of("logra", "k_in").unwrap() > 0);
        }
    }

    #[test]
    fn defaults_api() {
        let doc = parse("[a]\n").unwrap();
        assert_eq!(doc.float_or("a", "missing", 2.5), 2.5);
        assert_eq!(doc.str_or("a", "missing", "d"), "d");
    }
}
