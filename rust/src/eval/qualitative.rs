//! Qualitative-accuracy experiment (Figure 5 / Appendix A): query the
//! valuation system with MODEL GENERATIONS and inspect the most valuable
//! training documents. On the synthetic topic-labelled corpus the paper's
//! "do they look similar?" judgement becomes a measurable statistic: the
//! topic-match rate between each query and its top-k valued documents.
//! Uses ℓ-RelatIF, as the paper does, to suppress gradient-norm outliers.

use std::path::Path;

use anyhow::Result;

use crate::coordinator::{projected_grads, run_logging, LoggingOptions};
use crate::data::corpus::{generate as gen_corpus, CorpusSpec, TOPIC_NAMES};
use crate::hessian::random_projections;
use crate::model::dataset::Dataset;
use crate::model::generate::generate;
use crate::model::trainer::Trainer;
use crate::runtime::Runtime;
use crate::util::rng::Pcg32;
use crate::valuation::{Normalization, QueryEngine};

#[derive(Clone, Debug)]
pub struct Retrieved {
    pub score: f64,
    pub doc_id: u64,
    pub topic: usize,
    pub snippet: String,
}

#[derive(Clone, Debug)]
pub struct QueryCase {
    pub prompt_topic: usize,
    pub generated_text: String,
    pub generated_topic: Option<usize>,
    pub top: Vec<Retrieved>,
}

#[derive(Clone, Debug)]
pub struct QualitativeOutput {
    pub cases: Vec<QueryCase>,
    /// Fraction of retrieved top-k docs whose topic matches the query
    /// prompt's topic (the quantitative proxy for Fig. 5 similarity).
    pub topic_match_rate: f64,
    /// Same rate when retrieving RANDOM docs (chance baseline ≈ 1/8).
    pub chance_rate: f64,
}

/// Run the qualitative experiment on an LM config.
pub fn run_qualitative(
    repo_root: &Path,
    config_name: &str,
    n_train: usize,
    n_queries: usize,
    topk: usize,
    train_epochs: usize,
) -> Result<QualitativeOutput> {
    let rt = Runtime::open_named(repo_root, config_name)?;
    let man = rt.manifest.clone();
    anyhow::ensure!(man.is_lm(), "qualitative experiment needs an LM config");
    let corpus = gen_corpus(CorpusSpec::new(man.vocab, man.seq_len, n_train, 21));
    let ds = Dataset::Lm(&corpus);

    // Train the model on the corpus so generations carry topic signal.
    let trainer = Trainer::new(&rt);
    let mut st = trainer.init(3)?;
    let all: Vec<usize> = (0..ds.len()).collect();
    let mut rng = Pcg32::seeded(5);
    trainer.train(&mut st, &ds, &all, train_epochs, &mut rng)?;

    // Logging phase.
    let proj = random_projections(&man, &mut rng);
    let dir = repo_root.join("runs").join("qualitative").join(config_name);
    std::fs::create_dir_all(&dir)?;
    let (store, hessian, _) =
        run_logging(&rt, &ds, &st.params, &proj, &dir.join("store"), &LoggingOptions::default())?;
    let precond = hessian.unwrap().preconditioner(0.1)?;
    let engine = QueryEngine::new(&rt, &store, &precond);

    // Queries: model generations from topic-seeded prompts.
    let spec = CorpusSpec::new(man.vocab, man.seq_len, 1, 777);
    let mut cases = Vec::new();
    let mut matches = 0usize;
    let mut total = 0usize;
    for qi in 0..n_queries {
        let topic = qi % TOPIC_NAMES.len();
        // Prompt: the first 8 tokens of a fresh doc from this topic.
        let mut prng = Pcg32::new(900 + qi as u64, 1);
        let full = crate::data::corpus::generate_doc(&corpus.layout, &spec, &mut prng, topic);
        let prompt = &full[..8.min(full.len())];
        let generated = generate(&rt, &st.params, prompt, 0.8, &mut rng)?;

        // Value the generation against the store.
        let gen_corpus_holder = one_doc_corpus(&corpus, &generated);
        let gen_ds = Dataset::Lm(&gen_corpus_holder);
        let (g, _) = projected_grads(&rt, &gen_ds, &[0], &st.params, &proj)?;
        let results = engine.query(&g, 1, topk, Normalization::RelatIf)?;
        let mut top = Vec::new();
        for &(score, id) in &results[0].top {
            let doc = &corpus.docs[id as usize];
            if doc.topic == topic {
                matches += 1;
            }
            total += 1;
            top.push(Retrieved {
                score,
                doc_id: id,
                topic: doc.topic,
                snippet: corpus.render(&doc.tokens[..16.min(doc.tokens.len())]),
            });
        }
        cases.push(QueryCase {
            prompt_topic: topic,
            generated_text: corpus.render(&generated[..24.min(generated.len())]),
            generated_topic: corpus.infer_topic(&generated),
            top,
        });
    }
    let chance_rate = 1.0 / TOPIC_NAMES.len() as f64;
    Ok(QualitativeOutput {
        cases,
        topic_match_rate: matches as f64 / total.max(1) as f64,
        chance_rate,
    })
}

/// Wrap a generated token sequence as a single-doc corpus for batching.
fn one_doc_corpus(like: &crate::data::Corpus, tokens: &[i32]) -> crate::data::Corpus {
    crate::data::Corpus {
        layout: like.layout.clone(),
        docs: vec![crate::data::corpus::Doc {
            id: u64::MAX,
            topic: 0,
            tokens: tokens.to_vec(),
        }],
        seq_len: like.seq_len,
    }
}

/// Human-readable report.
pub fn render(out: &QualitativeOutput) -> String {
    let mut s = format!(
        "topic-match rate of top-valued docs: {:.2} (chance {:.2})\n\n",
        out.topic_match_rate, out.chance_rate
    );
    for (i, c) in out.cases.iter().enumerate() {
        s.push_str(&format!(
            "--- query {} | prompt topic: {} | generated: {}\n",
            i, TOPIC_NAMES[c.prompt_topic], c.generated_text
        ));
        for r in &c.top {
            s.push_str(&format!(
                "    [{:+.3}] doc {} ({}) {}\n",
                r.score, r.doc_id, TOPIC_NAMES[r.topic], r.snippet
            ));
        }
    }
    s
}
