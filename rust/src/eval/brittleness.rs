//! Brittleness test (Ilyas et al. / paper §4.1): remove the top-k most
//! valuable train examples for a test point, retrain, and measure whether
//! the model's behaviour on that point degrades. Accurate valuation ⇒
//! small removals flip predictions (classification) or raise loss (LM).

use anyhow::Result;

use crate::linalg::Matrix;
use crate::model::dataset::Dataset;
use crate::model::trainer::Trainer;
use crate::util::rng::Pcg32;

/// Harness parameters (paper scale: 100 test points, k up to hundreds,
/// 3 retrain seeds; defaults here are single-core-budget scale — override
/// via CLI flags for full runs).
#[derive(Clone, Debug)]
pub struct BrittlenessConfig {
    pub removal_counts: Vec<usize>,
    pub retrain_seeds: Vec<u32>,
    pub epochs: usize,
}

impl Default for BrittlenessConfig {
    fn default() -> Self {
        BrittlenessConfig {
            removal_counts: vec![10, 40, 160],
            retrain_seeds: vec![100],
            epochs: 4,
        }
    }
}

/// Result for one method.
#[derive(Clone, Debug)]
pub struct BrittlenessResult {
    pub method: String,
    /// Per removal count k: classification → fraction of test examples
    /// flipped; LM → mean Δloss (retrained − base) over test examples.
    pub per_k: Vec<(usize, f64)>,
    pub retrains: usize,
}

/// Top-k train indices by value row (descending).
pub fn top_k_indices(values_row: &[f32], k: usize) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..values_row.len()).collect();
    idx.sort_by(|&a, &b| values_row[b].partial_cmp(&values_row[a]).unwrap());
    idx.truncate(k);
    idx
}

/// Run the brittleness protocol for one method's value matrix.
///
/// `values` is [test_indices.len(), n_train]. `base_test_loss[t]` /
/// `base_pred[t]` describe the full-data model on the chosen test points.
/// For classification (`labels` = Some), returns flip fractions; for LM
/// (None), mean loss increase.
#[allow(clippy::too_many_arguments)]
pub fn brittleness_eval(
    trainer: &Trainer,
    train_ds: &Dataset,
    test_ds: &Dataset,
    test_indices: &[usize],
    test_labels: Option<&[i32]>,
    base_test_loss: &[f32],
    values: &Matrix,
    method: &str,
    cfg: &BrittlenessConfig,
) -> Result<BrittlenessResult> {
    let n_train = train_ds.len();
    assert_eq!(values.rows, test_indices.len());
    assert_eq!(values.cols, n_train);
    let mut per_k = Vec::new();
    let mut retrains = 0usize;
    for &k in &cfg.removal_counts {
        let k = k.min(n_train.saturating_sub(1));
        let mut metric_acc = 0.0f64;
        let mut metric_n = 0usize;
        for (t, &ti) in test_indices.iter().enumerate() {
            let removed = top_k_indices(values.row(t), k);
            let removed_set: std::collections::HashSet<usize> =
                removed.into_iter().collect();
            let keep: Vec<usize> =
                (0..n_train).filter(|i| !removed_set.contains(i)).collect();
            for &seed in &cfg.retrain_seeds {
                let mut st = trainer.init(seed)?;
                let mut rng = Pcg32::new(seed as u64 + 17 * t as u64, 3);
                trainer.train(&mut st, train_ds, &keep, cfg.epochs, &mut rng)?;
                retrains += 1;
                match test_labels {
                    Some(labels) => {
                        let pred = trainer.predictions(&st, test_ds, &[ti])?[0];
                        if pred != labels[t] {
                            metric_acc += 1.0;
                        }
                        metric_n += 1;
                    }
                    None => {
                        let (losses, _) = trainer.eval(&st, test_ds, &[ti])?;
                        metric_acc += (losses[0] - base_test_loss[t]) as f64;
                        metric_n += 1;
                    }
                }
            }
        }
        per_k.push((k, metric_acc / metric_n.max(1) as f64));
    }
    Ok(BrittlenessResult { method: method.to_string(), per_k, retrains })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn top_k_orders_descending() {
        let vals = [0.1f32, 5.0, -2.0, 3.0, 3.0];
        assert_eq!(top_k_indices(&vals, 2), vec![1, 3]);
        assert_eq!(top_k_indices(&vals, 0), Vec::<usize>::new());
        assert_eq!(top_k_indices(&vals, 10), vec![1, 3, 4, 0, 2]);
    }
}
