//! Linear datamodeling score (Park et al. / paper §4.1).
//!
//! Sample random half-size train subsets S_i, retrain on each to get gold
//! test performance, and check (Spearman) whether the method's summed
//! values Σ_{j∈S_i} value(t, j) rank the subsets like the gold runs do.
//! Gold retrainings are method-independent — computed once per benchmark
//! and shared by every method (the dominant cost, so this sharing matters
//! on a single-core budget).

use anyhow::Result;

use crate::linalg::Matrix;
use crate::model::dataset::Dataset;
use crate::model::trainer::Trainer;
use crate::util::rng::Pcg32;
use crate::util::stats::{mean, spearman};

#[derive(Clone, Debug)]
pub struct LdsConfig {
    pub n_subsets: usize,
    /// |S_i| = frac * n_train (paper: 0.5).
    pub subset_frac: f64,
    pub gold_seeds: Vec<u32>,
    pub epochs: usize,
}

impl Default for LdsConfig {
    fn default() -> Self {
        LdsConfig { n_subsets: 16, subset_frac: 0.5, gold_seeds: vec![300], epochs: 4 }
    }
}

/// Draw the shared subset collection.
pub fn sample_subsets(n_train: usize, cfg: &LdsConfig, rng: &mut Pcg32) -> Vec<Vec<usize>> {
    let size = ((n_train as f64) * cfg.subset_frac).round() as usize;
    (0..cfg.n_subsets).map(|_| rng.sample_indices(n_train, size.max(1))).collect()
}

/// Gold matrix [n_subsets, n_test]: NEGATIVE mean test loss (higher =
/// better performance) of a model retrained on each subset.
pub fn lds_gold(
    trainer: &Trainer,
    train_ds: &Dataset,
    test_ds: &Dataset,
    test_indices: &[usize],
    subsets: &[Vec<usize>],
    cfg: &LdsConfig,
) -> Result<Matrix> {
    let mut gold = Matrix::zeros(subsets.len(), test_indices.len());
    for (si, subset) in subsets.iter().enumerate() {
        let mut acc = vec![0.0f64; test_indices.len()];
        for &seed in &cfg.gold_seeds {
            let mut st = trainer.init(seed)?;
            let mut rng = Pcg32::new(seed as u64 * 31 + si as u64, 5);
            trainer.train(&mut st, train_ds, subset, cfg.epochs, &mut rng)?;
            let (losses, _) = trainer.eval(&st, test_ds, test_indices)?;
            for (a, l) in acc.iter_mut().zip(&losses) {
                *a += -(*l as f64);
            }
        }
        for (t, a) in acc.iter().enumerate() {
            gold.data[si * test_indices.len() + t] = (a / cfg.gold_seeds.len() as f64) as f32;
        }
    }
    Ok(gold)
}

/// LDS for one method: mean Spearman over test examples between predicted
/// subset utility (sum of values over the subset) and gold performance.
/// The paper predicts test LOSS via summed values; since influence scores
/// estimate the gain in performance from including an example, predicted
/// utility = Σ values and gold = −loss correlate positively for a good
/// method.
pub fn lds_score(values: &Matrix, subsets: &[Vec<usize>], gold: &Matrix) -> f64 {
    let n_test = values.rows;
    assert_eq!(gold.cols, n_test);
    assert_eq!(gold.rows, subsets.len());
    let mut per_test = Vec::with_capacity(n_test);
    for t in 0..n_test {
        let row = values.row(t);
        let predicted: Vec<f64> = subsets
            .iter()
            .map(|s| s.iter().map(|&j| row[j] as f64).sum())
            .collect();
        let gold_col: Vec<f64> =
            (0..subsets.len()).map(|si| gold.at(si, t) as f64).collect();
        let rho = spearman(&predicted, &gold_col);
        if rho.is_finite() {
            per_test.push(rho);
        }
    }
    mean(&per_test)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn subsets_have_requested_size() {
        let mut rng = Pcg32::seeded(1);
        let cfg = LdsConfig { n_subsets: 5, subset_frac: 0.5, ..Default::default() };
        let subs = sample_subsets(100, &cfg, &mut rng);
        assert_eq!(subs.len(), 5);
        for s in &subs {
            assert_eq!(s.len(), 50);
            assert!(s.windows(2).all(|w| w[0] < w[1]));
        }
    }

    #[test]
    fn lds_perfect_for_additive_gold() {
        // Gold generated exactly as the sum of true per-example utilities
        // -> a method reporting those utilities scores Spearman 1.
        let mut rng = Pcg32::seeded(2);
        let n_train = 40;
        let n_test = 3;
        let true_vals = Matrix::random_normal(&mut rng, n_test, n_train, 1.0);
        let cfg = LdsConfig { n_subsets: 12, ..Default::default() };
        let subsets = sample_subsets(n_train, &cfg, &mut rng);
        let mut gold = Matrix::zeros(subsets.len(), n_test);
        for (si, s) in subsets.iter().enumerate() {
            for t in 0..n_test {
                let u: f32 = s.iter().map(|&j| true_vals.at(t, j)).sum();
                gold.data[si * n_test + t] = u;
            }
        }
        let rho = lds_score(&true_vals, &subsets, &gold);
        assert!((rho - 1.0).abs() < 1e-9, "rho={rho}");
        // A reversed method scores -1.
        let mut neg = true_vals.clone();
        neg.scale(-1.0);
        assert!((lds_score(&neg, &subsets, &gold) + 1.0).abs() < 1e-9);
    }

    #[test]
    fn lds_random_near_zero() {
        let mut rng = Pcg32::seeded(3);
        let n_train = 60;
        let true_vals = Matrix::random_normal(&mut rng, 1, n_train, 1.0);
        let cfg = LdsConfig { n_subsets: 64, ..Default::default() };
        let subsets = sample_subsets(n_train, &cfg, &mut rng);
        let mut gold = Matrix::zeros(subsets.len(), 1);
        for (si, s) in subsets.iter().enumerate() {
            gold.data[si] = s.iter().map(|&j| true_vals.at(0, j)).sum();
        }
        let junk = Matrix::random_normal(&mut rng, 1, n_train, 1.0);
        let rho = lds_score(&junk, &subsets, &gold);
        assert!(rho.abs() < 0.45, "rho={rho}");
    }
}
