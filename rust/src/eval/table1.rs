//! Table-1 reproduction: memory & compute efficiency of LoGra vs EKFAC
//! influence on the largest local LM config — logging throughput
//! (tokens/s), influence throughput ((train,test) pairs/s), peak memory,
//! and storage. Absolute numbers reflect this CPU testbed; the paper's
//! claim under test is the SHAPE: LoGra's influence throughput is orders
//! of magnitude higher at lower memory, at the price of storage.

use std::path::Path;

use anyhow::Result;

use crate::baselines::{EkfacValuator, Valuator};
use crate::coordinator::{projected_grads, run_logging, LoggingOptions};
use crate::data::corpus::{generate as gen_corpus, CorpusSpec};
use crate::hessian::random_projections;
use crate::model::dataset::Dataset;
use crate::model::trainer::Trainer;
use crate::runtime::Runtime;
use crate::util::memory::{human_bytes, peak_rss_bytes};
use crate::util::rng::Pcg32;
use crate::util::Timer;
use crate::valuation::{Normalization, QueryEngine};

#[derive(Clone, Debug)]
pub struct Table1Row {
    pub system: String,
    pub phase: String, // "logging" | "influence"
    pub batch: String,
    pub throughput: f64,
    pub unit: String,
    pub peak_rss: u64,
    pub storage_bytes: u64,
}

impl Table1Row {
    pub fn render(&self) -> String {
        format!(
            "| {} | {} | {} | {:.1} {} | {} | {} |",
            self.system,
            self.phase,
            self.batch,
            self.throughput,
            self.unit,
            human_bytes(self.peak_rss),
            if self.storage_bytes > 0 {
                human_bytes(self.storage_bytes)
            } else {
                "-".to_string()
            }
        )
    }
}

pub const TABLE1_HEADER: &str =
    "| system | phase | batch | throughput | peak RSS | storage |\n|---|---|---|---|---|---|";

/// Run the efficiency comparison on `config_name`.
pub fn run_table1(
    repo_root: &Path,
    config_name: &str,
    n_train: usize,
    n_test: usize,
    train_steps: usize,
) -> Result<Vec<Table1Row>> {
    let rt = Runtime::open_named(repo_root, config_name)?;
    let man = rt.manifest.clone();
    anyhow::ensure!(man.is_lm(), "table1 runs on an LM config");
    let corpus = gen_corpus(CorpusSpec::new(man.vocab, man.seq_len, n_train, 7));
    let queries = gen_corpus(CorpusSpec::new(man.vocab, man.seq_len, n_test.max(1), 8));
    let train_ds = Dataset::Lm(&corpus);
    let test_ds = Dataset::Lm(&queries);

    // Briefly trained model (efficiency is parameter-value independent,
    // but a non-degenerate model keeps gradients representative).
    let trainer = Trainer::new(&rt);
    let mut st = trainer.init(0)?;
    let all: Vec<usize> = (0..train_ds.len()).collect();
    let mut rng = Pcg32::seeded(1);
    if train_steps > 0 {
        let order: Vec<usize> =
            (0..(train_steps * man.train_batch).min(all.len())).collect();
        trainer.train(&mut st, &train_ds, &order, 1, &mut rng)?;
    }
    let params = st.params.clone();
    let proj = random_projections(&man, &mut rng);
    let run_dir = repo_root.join("runs").join("table1").join(config_name);
    std::fs::create_dir_all(&run_dir)?;

    let mut rows = Vec::new();
    let tokens_per_ex = man.seq_len as f64;

    // ---- LoGra logging (store write + Fisher accumulation).
    crate::util::memory::ledger_reset_peak();
    let (store, hessian, rep) = run_logging(
        &rt,
        &train_ds,
        &params,
        &proj,
        &run_dir.join("store"),
        &LoggingOptions::default(),
    )?;
    rows.push(Table1Row {
        system: "LoGra".into(),
        phase: "logging".into(),
        batch: format!("{}", man.log_batch),
        throughput: rep.tokens_per_sec,
        unit: "tokens/s".into(),
        peak_rss: rep.peak_rss_bytes,
        storage_bytes: rep.storage_bytes,
    });

    // ---- EKFAC logging (KFAC fit + corrected eigenvalue fit).
    let t0 = Timer::start();
    let mut ek = EkfacValuator::new(&rt, &train_ds, &test_ds, &params);
    // First values() call performs the full EKFAC fit; time it separately
    // from the per-query part by fitting on a single query afterwards.
    let fit_probe: Vec<usize> = vec![0];
    let _ = ek.values(&fit_probe)?; // fit + one recompute pass
    let ekfac_log_secs = t0.seconds();
    let ekfac_tokens = 2.0 * n_train as f64 * tokens_per_ex; // cov pass + rotate pass
    rows.push(Table1Row {
        system: "EKFAC".into(),
        phase: "logging".into(),
        batch: format!("{}", man.log_batch),
        throughput: ekfac_tokens / ekfac_log_secs,
        unit: "tokens/s".into(),
        peak_rss: peak_rss_bytes(),
        storage_bytes: 0, // EKFAC stores no per-example gradients
    });

    // ---- LoGra influence (store scan).
    let precond = hessian.unwrap().preconditioner(0.1)?;
    let engine = QueryEngine::new(&rt, &store, &precond);
    let test_idx: Vec<usize> = (0..n_test.min(test_ds.len())).collect();
    let (tg, _) = projected_grads(&rt, &test_ds, &test_idx, &params, &proj)?;
    let t1 = Timer::start();
    let _vals = engine.values_matrix(&tg, test_idx.len(), Normalization::None)?;
    let secs = t1.seconds();
    let pairs = (test_idx.len() * store.rows()) as f64;
    rows.push(Table1Row {
        system: "LoGra".into(),
        phase: "influence".into(),
        batch: format!("tr={} te={}", man.train_chunk, test_idx.len()),
        throughput: pairs / secs,
        unit: "pairs/s".into(),
        peak_rss: peak_rss_bytes(),
        storage_bytes: store.storage_bytes(),
    });

    // ---- EKFAC influence (recompute all train grads per query batch).
    let t2 = Timer::start();
    let _ = ek.values(&test_idx)?;
    let secs = t2.seconds();
    let pairs = (test_idx.len() * n_train) as f64;
    rows.push(Table1Row {
        system: "EKFAC".into(),
        phase: "influence".into(),
        batch: format!("tr={} te={}", man.log_batch, test_idx.len()),
        throughput: pairs / secs,
        unit: "pairs/s".into(),
        peak_rss: peak_rss_bytes(),
        storage_bytes: 0,
    });

    Ok(rows)
}
