//! Counterfactual evaluation harness (paper §4.1 / Figure 4): the
//! brittleness test and the linear datamodeling score, plus the Fig-4
//! orchestration that runs every method on every benchmark.

pub mod brittleness;
pub mod fig4;
pub mod lds;
pub mod qualitative;
pub mod table1;

pub use brittleness::{brittleness_eval, BrittlenessConfig, BrittlenessResult};
pub use lds::{lds_gold, lds_score, sample_subsets, LdsConfig};
