//! Figure-4 orchestration: run every valuation method on one benchmark
//! (mlp_fmnist / mlp_cifar / lm_wikitext) through both counterfactual
//! protocols. Used by the `logra fig4` CLI and `benches/fig4_counterfactual`.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, Result};

use crate::baselines::{
    EkfacValuator, GradDotValuator, LograInit, LograValuator, RepSimValuator,
    TrakValuator, Valuator,
};
use crate::data::corpus::{generate as gen_corpus, CorpusSpec};
use crate::data::images::{generate as gen_images, generate_eval, ImageSpec};
use crate::eval::brittleness::{brittleness_eval, BrittlenessConfig, BrittlenessResult};
use crate::eval::lds::{lds_gold, lds_score, sample_subsets, LdsConfig};
use crate::model::dataset::Dataset;
use crate::model::trainer::Trainer;
use crate::runtime::Runtime;
use crate::util::rng::Pcg32;

/// Experiment scale knobs (defaults sized for the single-core testbed;
/// paper-scale runs pass bigger numbers via CLI flags).
#[derive(Clone, Debug)]
pub struct Fig4Scale {
    pub n_train: usize,
    pub n_test_pool: usize,
    pub n_test: usize,
    pub base_epochs: usize,
    pub brittle: BrittlenessConfig,
    pub lds: LdsConfig,
    pub methods: Vec<String>,
    pub seed: u64,
    pub run_brittleness: bool,
    pub run_lds: bool,
}

impl Default for Fig4Scale {
    fn default() -> Self {
        Fig4Scale {
            n_train: 512,
            n_test_pool: 64,
            n_test: 8,
            base_epochs: 4,
            brittle: BrittlenessConfig::default(),
            lds: LdsConfig::default(),
            methods: vec![
                "logra-pca".into(),
                "logra-random".into(),
                "ekfac-if".into(),
                "trak".into(),
                "grad-dot".into(),
                "rep-sim".into(),
            ],
            seed: 42,
            run_brittleness: true,
            run_lds: true,
        }
    }
}

/// One method's outcomes.
#[derive(Clone, Debug)]
pub struct MethodOutcome {
    pub method: String,
    pub brittleness: Option<BrittlenessResult>,
    pub lds: Option<f64>,
    pub values_seconds: f64,
}

#[derive(Clone, Debug)]
pub struct Fig4Output {
    pub benchmark: String,
    pub kind: String,
    pub outcomes: Vec<MethodOutcome>,
    pub gold_retrains: usize,
    pub n_train: usize,
    pub n_test: usize,
}

/// Datasets owned by a benchmark run (kept alive for the borrows below).
pub enum BenchData {
    Mlp { train: crate::data::ImageSet, test: crate::data::ImageSet },
    Lm { train: crate::data::Corpus, test: crate::data::Corpus },
}

impl BenchData {
    pub fn build(man: &crate::runtime::Manifest, name: &str, scale: &Fig4Scale) -> Result<Self> {
        if man.is_lm() {
            let spec = CorpusSpec::new(man.vocab, man.seq_len, scale.n_train, scale.seed);
            let tspec = CorpusSpec::new(
                man.vocab,
                man.seq_len,
                scale.n_test_pool,
                scale.seed + 9001,
            );
            Ok(BenchData::Lm { train: gen_corpus(spec), test: gen_corpus(tspec) })
        } else {
            let mk = |n: usize| -> ImageSpec {
                if name.contains("cifar") {
                    ImageSpec::cifar_like(man.input_dim, man.classes, n, scale.seed)
                } else {
                    ImageSpec::fmnist_like(man.input_dim, man.classes, n, scale.seed)
                }
            };
            let train = gen_images(mk(scale.n_train));
            let test = generate_eval(mk(scale.n_train), scale.n_test_pool);
            Ok(BenchData::Mlp { train, test })
        }
    }

    pub fn datasets(&self) -> (Dataset<'_>, Dataset<'_>) {
        match self {
            BenchData::Mlp { train, test } => (Dataset::Mlp(train), Dataset::Mlp(test)),
            BenchData::Lm { train, test } => (Dataset::Lm(train), Dataset::Lm(test)),
        }
    }

    pub fn test_labels(&self) -> Option<Vec<i32>> {
        match self {
            BenchData::Mlp { test, .. } => Some(test.labels.clone()),
            BenchData::Lm { .. } => None,
        }
    }
}

fn build_valuator<'a>(
    name: &str,
    rt: &'a Runtime,
    train: &'a Dataset<'a>,
    test: &'a Dataset<'a>,
    params: &'a [f32],
    run_dir: &Path,
    seed: u64,
) -> Result<Box<dyn Valuator + 'a>> {
    const DAMP: f32 = 0.1;
    Ok(match name {
        "logra-pca" => Box::new(LograValuator::build(
            rt,
            train,
            test,
            params,
            LograInit::Pca,
            run_dir.join("store-pca"),
            DAMP,
            seed,
        )?),
        "logra-random" => Box::new(LograValuator::build(
            rt,
            train,
            test,
            params,
            LograInit::Random,
            run_dir.join("store-rand"),
            DAMP,
            seed,
        )?),
        "ekfac-if" => Box::new(EkfacValuator::new(rt, train, test, params)),
        "trak" => Box::new(TrakValuator::new(rt, train, test, params, 64, DAMP, seed)),
        "grad-dot" => Box::new(GradDotValuator { rt, train, test, params }),
        "rep-sim" => Box::new(RepSimValuator::new(rt, train, test, params)),
        "random" => Box::new(RandomValuator { n_train: train.len(), seed }),
        other => return Err(anyhow!("unknown method {other:?}")),
    })
}

/// Control: i.i.d. Gaussian values. Calibrates both protocols — LDS should
/// be ≈0 and brittleness should match random-removal damage.
struct RandomValuator {
    n_train: usize,
    seed: u64,
}

impl Valuator for RandomValuator {
    fn name(&self) -> String {
        "random".into()
    }

    fn values(&mut self, test_indices: &[usize]) -> Result<crate::linalg::Matrix> {
        let mut rng = Pcg32::new(self.seed, 99);
        Ok(crate::linalg::Matrix::random_normal(
            &mut rng,
            test_indices.len(),
            self.n_train,
            1.0,
        ))
    }
}

/// Run one Figure-4 benchmark end to end.
pub fn run_fig4(repo_root: &Path, config_name: &str, scale: &Fig4Scale) -> Result<Fig4Output> {
    let rt = Runtime::open_named(repo_root, config_name)?;
    let man = rt.manifest.clone();
    let data = BenchData::build(&man, config_name, scale)?;
    let (train_ds, test_ds) = data.datasets();
    let trainer = Trainer::new(&rt);
    let run_dir: PathBuf = repo_root.join("runs").join("fig4").join(config_name);
    std::fs::create_dir_all(&run_dir)?;

    // Base model on the full training set.
    let mut base = trainer.init(1)?;
    let all: Vec<usize> = (0..train_ds.len()).collect();
    let mut rng = Pcg32::new(scale.seed, 2);
    trainer.train(&mut base, &train_ds, &all, scale.base_epochs, &mut rng)?;

    // Test selection: correctly classified points (classification) or the
    // first pool entries (LM).
    let pool: Vec<usize> = (0..test_ds.len()).collect();
    let test_indices: Vec<usize> = if let Some(labels) = data.test_labels() {
        let preds = trainer.predictions(&base, &test_ds, &pool)?;
        pool.iter()
            .copied()
            .filter(|&i| preds[i] == labels[i])
            .take(scale.n_test)
            .collect()
    } else {
        pool.iter().copied().take(scale.n_test).collect()
    };
    anyhow::ensure!(!test_indices.is_empty(), "no eligible test examples");
    let (base_losses, _) = trainer.eval(&base, &test_ds, &test_indices)?;
    let test_labels: Option<Vec<i32>> = data
        .test_labels()
        .map(|ls| test_indices.iter().map(|&i| ls[i]).collect());

    // Shared LDS gold runs.
    let mut rng_lds = Pcg32::new(scale.seed, 11);
    let subsets = sample_subsets(train_ds.len(), &scale.lds, &mut rng_lds);
    let gold = if scale.run_lds {
        Some(lds_gold(&trainer, &train_ds, &test_ds, &test_indices, &subsets, &scale.lds)?)
    } else {
        None
    };
    let gold_retrains = if scale.run_lds {
        subsets.len() * scale.lds.gold_seeds.len()
    } else {
        0
    };

    let mut outcomes = Vec::new();
    for method in &scale.methods {
        let t0 = crate::util::Timer::start();
        let mut v = build_valuator(
            method,
            &rt,
            &train_ds,
            &test_ds,
            &base.params,
            &run_dir,
            scale.seed,
        )?;
        let values = v.values(&test_indices)?;
        let values_seconds = t0.seconds();
        let brit = if scale.run_brittleness {
            Some(brittleness_eval(
                &trainer,
                &train_ds,
                &test_ds,
                &test_indices,
                test_labels.as_deref(),
                &base_losses,
                &values,
                method,
                &scale.brittle,
            )?)
        } else {
            None
        };
        let lds = gold.as_ref().map(|g| lds_score(&values, &subsets, g));
        println!(
            "[fig4 {config_name}] {method}: values {values_seconds:.1}s, lds {:?}, brittleness {:?}",
            lds,
            brit.as_ref().map(|b| &b.per_k)
        );
        outcomes.push(MethodOutcome {
            method: method.clone(),
            brittleness: brit,
            lds,
            values_seconds,
        });
    }

    Ok(Fig4Output {
        benchmark: config_name.to_string(),
        kind: man.kind.clone(),
        outcomes,
        gold_retrains,
        n_train: train_ds.len(),
        n_test: test_indices.len(),
    })
}

/// Render a Fig-4 output as a markdown table block.
pub fn render_markdown(out: &Fig4Output) -> String {
    let mut s = format!(
        "### {} ({}; n_train={}, n_test={})\n\n",
        out.benchmark, out.kind, out.n_train, out.n_test
    );
    let metric = if out.kind == "mlp" { "flip-frac" } else { "Δloss" };
    s.push_str(&format!("| method | LDS | {metric} per k |\n|---|---|---|\n"));
    for o in &out.outcomes {
        let lds = o.lds.map(|v| format!("{v:.3}")).unwrap_or_else(|| "-".into());
        let brit = o
            .brittleness
            .as_ref()
            .map(|b| {
                b.per_k
                    .iter()
                    .map(|(k, v)| format!("k={k}: {v:.3}"))
                    .collect::<Vec<_>>()
                    .join(", ")
            })
            .unwrap_or_else(|| "-".into());
        s.push_str(&format!("| {} | {} | {} |\n", o.method, lds, brit));
    }
    s
}
