//! PJRT runtime: load AOT HLO-text artifacts, compile once, execute many.
//!
//! Mirrors `/opt/xla-example/load_hlo`: `HloModuleProto::from_text_file`
//! -> `XlaComputation::from_proto` -> `client.compile` -> `execute`.
//! Executables are cached per entry name; every program returns a tuple
//! (aot.py lowers with `return_tuple=True`) which `run` flattens.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

use anyhow::{anyhow, Context, Result};
use xla::{Literal, PjRtClient, PjRtLoadedExecutable, XlaComputation};

use super::manifest::Manifest;

/// A compiled artifact directory: one PJRT client + lazily compiled
/// executables for each entry point.
pub struct Runtime {
    client: PjRtClient,
    dir: PathBuf,
    pub manifest: Manifest,
    cache: RefCell<HashMap<String, Rc<PjRtLoadedExecutable>>>,
    /// Cumulative executions per entry (metrics / tests).
    calls: RefCell<HashMap<String, u64>>,
}

impl Runtime {
    /// Open an artifact directory produced by `make artifacts`.
    pub fn open(dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(dir)?;
        let client = PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Runtime {
            client,
            dir: dir.to_path_buf(),
            manifest,
            cache: RefCell::new(HashMap::new()),
            calls: RefCell::new(HashMap::new()),
        })
    }

    /// Open `artifacts/<name>` relative to the repo root.
    pub fn open_named(root: &Path, name: &str) -> Result<Self> {
        Self::open(&root.join("artifacts").join(name))
    }

    /// Compile (or fetch from cache) an entry point.
    pub fn executable(&self, entry: &str) -> Result<Rc<PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.borrow().get(entry) {
            return Ok(exe.clone());
        }
        let path = self.dir.join(format!("{entry}.hlo.txt"));
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("load {}: {e:?}", path.display()))?;
        let comp = XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {entry}: {e:?}"))?;
        let exe = Rc::new(exe);
        self.cache.borrow_mut().insert(entry.to_string(), exe.clone());
        Ok(exe)
    }

    /// Execute an entry with literal inputs; returns the flattened tuple
    /// of output literals. Prefer [`Runtime::run_ref`] on hot paths —
    /// this convenience wrapper borrows internally, so both avoid deep
    /// literal copies, but `run_ref` lets callers reuse long-lived
    /// literals (parameters, projections) across calls without cloning.
    pub fn run(&self, entry: &str, args: &[Literal]) -> Result<Vec<Literal>> {
        let refs: Vec<&Literal> = args.iter().collect();
        self.run_ref(entry, &refs)
    }

    /// Execute with borrowed inputs (no `Literal::clone`, which is a deep
    /// C++-side copy — §Perf log in EXPERIMENTS.md).
    pub fn run_ref(&self, entry: &str, args: &[&Literal]) -> Result<Vec<Literal>> {
        let exe = self.executable(entry).with_context(|| entry.to_string())?;
        let out = exe
            .execute::<&Literal>(args)
            .map_err(|e| anyhow!("execute {entry}: {e:?}"))?;
        let lit = out[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch {entry}: {e:?}"))?;
        *self.calls.borrow_mut().entry(entry.to_string()).or_insert(0) += 1;
        Ok(lit.to_tuple().map_err(|e| anyhow!("untuple {entry}: {e:?}"))?)
    }

    /// Number of `run` calls per entry so far.
    pub fn call_count(&self, entry: &str) -> u64 {
        self.calls.borrow().get(entry).copied().unwrap_or(0)
    }

    /// Warm the executable cache for a set of entries (pays the one-time
    /// XLA compile cost up front, outside timed regions).
    pub fn warmup(&self, entries: &[&str]) -> Result<()> {
        for e in entries {
            self.executable(e)?;
        }
        Ok(())
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }
}
