//! PJRT runtime layer: artifact loading, executable caching, literal
//! helpers, manifest parsing. Python never appears at runtime — the HLO
//! programs under `artifacts/` are the only interface to L1/L2.

pub mod client;
pub mod literal;
pub mod manifest;

pub use client::Runtime;
pub use manifest::{Manifest, ModuleInfo, ParamInfo};
