//! Artifact manifest parser.
//!
//! `python/compile/aot.py` writes `manifest.txt` next to the HLO programs;
//! it records every layout convention the coordinator relies on: the flat
//! parameter table, the LoGra module table with gradient-block /
//! projection-vector / covariance offsets, and the batch shapes each
//! entry point was closed over.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, Context, Result};

/// One LoGra-instrumented module (linear layer) as recorded by aot.py.
#[derive(Clone, Debug)]
pub struct ModuleInfo {
    pub name: String,
    pub n_in: usize,
    pub n_out: usize,
    /// Offset/length of this module's block in a projected gradient row.
    pub g_off: usize,
    pub g_len: usize,
    /// Offset/length in a full-rank (EKFAC) gradient row.
    pub gfull_off: usize,
    pub gfull_len: usize,
    /// Offset of this module's (P_i, P_o) pair in the flat projection vec.
    pub p_off: usize,
    /// Offset in the full-rank projection vec.
    pub pfull_off: usize,
    /// Offset of this module's (C_F, C_B) pair in the flat covariance vec.
    pub cov_off: usize,
}

/// One named parameter tensor in the flat parameter vector.
#[derive(Clone, Debug)]
pub struct ParamInfo {
    pub name: String,
    pub off: usize,
    pub shape: Vec<usize>,
}

impl ParamInfo {
    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Parsed manifest for one artifact directory.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub name: String,
    pub kind: String, // "lm" | "mlp"
    pub n_params: usize,
    pub k_in: usize,
    pub k_out: usize,
    pub k_total: usize,
    pub k_full: usize,
    pub proj_len: usize,
    pub proj_len_full: usize,
    pub cov_len: usize,
    pub train_batch: usize,
    pub log_batch: usize,
    pub test_batch: usize,
    pub train_chunk: usize,
    /// LM: vocab/seq_len/d_model. MLP: input_dim/classes. 0 when absent.
    pub vocab: usize,
    pub seq_len: usize,
    pub input_dim: usize,
    pub classes: usize,
    pub repr_dim: usize,
    pub modules: Vec<ModuleInfo>,
    pub params: Vec<ParamInfo>,
    pub entries: Vec<String>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("read {}", path.display()))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Self> {
        let mut kv: BTreeMap<&str, &str> = BTreeMap::new();
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| anyhow!("bad manifest line {line:?}"))?;
            kv.insert(k, v);
        }
        let get = |k: &str| -> Result<&str> {
            kv.get(k).copied().ok_or_else(|| anyhow!("manifest missing key {k}"))
        };
        let get_usize =
            |k: &str| -> Result<usize> { Ok(get(k)?.parse::<usize>()?) };
        let opt_usize = |k: &str| -> usize {
            kv.get(k).and_then(|v| v.parse().ok()).unwrap_or(0)
        };

        let n_modules = get_usize("n_modules")?;
        let mut modules = Vec::with_capacity(n_modules);
        for i in 0..n_modules {
            let f = |field: &str| get_usize(&format!("module.{i}.{field}"));
            modules.push(ModuleInfo {
                name: get(&format!("module.{i}.name"))?.to_string(),
                n_in: f("n_in")?,
                n_out: f("n_out")?,
                g_off: f("g_off")?,
                g_len: f("g_len")?,
                gfull_off: f("gfull_off")?,
                gfull_len: f("gfull_len")?,
                p_off: f("p_off")?,
                pfull_off: f("pfull_off")?,
                cov_off: f("cov_off")?,
            });
        }
        let n_tensors = get_usize("n_param_tensors")?;
        let mut params = Vec::with_capacity(n_tensors);
        for i in 0..n_tensors {
            let shape: Vec<usize> = get(&format!("param.{i}.shape"))?
                .split('x')
                .map(|d| d.parse::<usize>())
                .collect::<std::result::Result<_, _>>()?;
            params.push(ParamInfo {
                name: get(&format!("param.{i}.name"))?.to_string(),
                off: get_usize(&format!("param.{i}.off"))?,
                shape,
            });
        }
        let man = Manifest {
            name: get("name")?.to_string(),
            kind: get("kind")?.to_string(),
            n_params: get_usize("n_params")?,
            k_in: get_usize("k_in")?,
            k_out: get_usize("k_out")?,
            k_total: get_usize("k_total")?,
            k_full: get_usize("k_full")?,
            proj_len: get_usize("proj_len")?,
            proj_len_full: get_usize("proj_len_full")?,
            cov_len: get_usize("cov_len")?,
            train_batch: get_usize("train_batch")?,
            log_batch: get_usize("log_batch")?,
            test_batch: get_usize("test_batch")?,
            train_chunk: get_usize("train_chunk")?,
            vocab: opt_usize("vocab"),
            seq_len: opt_usize("seq_len"),
            input_dim: opt_usize("input_dim"),
            classes: opt_usize("classes"),
            repr_dim: opt_usize("repr_dim"),
            modules,
            params,
            entries: get("entries")?.split(',').map(str::to_string).collect(),
        };
        man.validate()?;
        Ok(man)
    }

    /// Internal consistency checks (offsets tile, totals match).
    pub fn validate(&self) -> Result<()> {
        let mut g = 0;
        let mut gf = 0;
        for m in &self.modules {
            if m.g_off != g || m.gfull_off != gf {
                return Err(anyhow!("module {} offsets out of order", m.name));
            }
            g += m.g_len;
            gf += m.gfull_len;
        }
        if g != self.k_total {
            return Err(anyhow!("gradient blocks sum {g} != k_total {}", self.k_total));
        }
        if gf != self.k_full {
            return Err(anyhow!("full blocks sum {gf} != k_full {}", self.k_full));
        }
        let mut off = 0;
        for p in &self.params {
            if p.off != off {
                return Err(anyhow!("param {} offset gap", p.name));
            }
            off += p.len();
        }
        if off != self.n_params {
            return Err(anyhow!("param table sum {off} != n_params {}", self.n_params));
        }
        Ok(())
    }

    /// Param lookup by name.
    pub fn param(&self, name: &str) -> Option<&ParamInfo> {
        self.params.iter().find(|p| p.name == name)
    }

    pub fn is_lm(&self) -> bool {
        self.kind == "lm"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> String {
        [
            "name=t",
            "kind=mlp",
            "n_params=20",
            "k_in=2",
            "k_out=2",
            "k_total=8",
            "k_full=20",
            "proj_len=16",
            "proj_len_full=29",
            "train_batch=4",
            "log_batch=4",
            "test_batch=2",
            "train_chunk=8",
            "input_dim=3",
            "classes=2",
            "repr_dim=4",
            "cov_len=29",
            "n_modules=2",
            "module.0.name=fc0",
            "module.0.n_in=3",
            "module.0.n_out=4",
            "module.0.g_off=0",
            "module.0.g_len=4",
            "module.0.gfull_off=0",
            "module.0.gfull_len=12",
            "module.0.p_off=0",
            "module.0.pfull_off=0",
            "module.0.cov_off=0",
            "module.1.name=fc1",
            "module.1.n_in=4",
            "module.1.n_out=2",
            "module.1.g_off=4",
            "module.1.g_len=4",
            "module.1.gfull_off=12",
            "module.1.gfull_len=8",
            "module.1.p_off=14",
            "module.1.pfull_off=25",
            "module.1.cov_off=25",
            "n_param_tensors=2",
            "param.0.name=fc0.w",
            "param.0.off=0",
            "param.0.shape=4x3",
            "param.1.name=fc1.w",
            "param.1.off=12",
            "param.1.shape=2x4",
            "entries=init,score",
        ]
        .join("\n")
    }

    #[test]
    fn parses_and_validates() {
        let m = Manifest::parse(&sample()).unwrap();
        assert_eq!(m.modules.len(), 2);
        assert_eq!(m.modules[1].g_off, 4);
        assert_eq!(m.param("fc1.w").unwrap().off, 12);
        assert_eq!(m.entries, vec!["init", "score"]);
        assert!(!m.is_lm());
    }

    #[test]
    fn rejects_offset_gaps() {
        let bad = sample().replace("module.1.g_off=4", "module.1.g_off=5");
        assert!(Manifest::parse(&bad).is_err());
    }

    #[test]
    fn rejects_param_total_mismatch() {
        let bad = sample().replace("n_params=20", "n_params=21");
        assert!(Manifest::parse(&bad).is_err());
    }

    #[test]
    fn real_manifests_parse_if_built() {
        let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !root.exists() {
            return; // `make artifacts` not run yet
        }
        for cfg in ["lm_tiny", "mlp_fmnist"] {
            let dir = root.join(cfg);
            if dir.exists() {
                let m = Manifest::load(&dir).unwrap();
                assert_eq!(m.name, cfg);
                assert!(m.k_total > 0);
                assert!(m.entries.contains(&"logra_log".to_string()));
            }
        }
    }
}
