//! Literal construction/extraction helpers over the `xla` crate.
//!
//! The AOT calling convention is flat f32 vectors + integer token/label
//! tensors; these helpers build such literals from slices without
//! intermediate copies beyond the one host->literal transfer.

use anyhow::{anyhow, Result};
use xla::{ElementType, Literal};

/// f32 literal with the given dims from a host slice (row-major).
pub fn f32_lit(dims: &[usize], data: &[f32]) -> Result<Literal> {
    let n: usize = dims.iter().product();
    if n != data.len() {
        return Err(anyhow!("f32_lit: {dims:?} needs {n} values, got {}", data.len()));
    }
    let bytes =
        unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4) };
    Ok(Literal::create_from_shape_and_untyped_data(ElementType::F32, dims, bytes)?)
}

/// i32 literal with the given dims.
pub fn i32_lit(dims: &[usize], data: &[i32]) -> Result<Literal> {
    let n: usize = dims.iter().product();
    if n != data.len() {
        return Err(anyhow!("i32_lit: {dims:?} needs {n} values, got {}", data.len()));
    }
    let bytes =
        unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4) };
    Ok(Literal::create_from_shape_and_untyped_data(ElementType::S32, dims, bytes)?)
}

/// u32 scalar literal (the init seed).
pub fn u32_scalar(v: u32) -> Literal {
    Literal::scalar(v)
}

/// i32 scalar literal (the train step counter).
pub fn i32_scalar(v: i32) -> Literal {
    Literal::scalar(v)
}

/// Extract a literal into a f32 vec (converting if needed).
pub fn to_f32_vec(lit: &Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}

/// Extract scalar f32.
pub fn to_f32_scalar(lit: &Literal) -> Result<f32> {
    Ok(lit.get_first_element::<f32>()?)
}

/// Extract scalar i32.
pub fn to_i32_scalar(lit: &Literal) -> Result<i32> {
    Ok(lit.get_first_element::<i32>()?)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_roundtrip() {
        let data = vec![1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        let lit = f32_lit(&[2, 3], &data).unwrap();
        assert_eq!(lit.element_count(), 6);
        assert_eq!(to_f32_vec(&lit).unwrap(), data);
    }

    #[test]
    fn i32_roundtrip() {
        let data = vec![1i32, -2, 3];
        let lit = i32_lit(&[3], &data).unwrap();
        assert_eq!(lit.to_vec::<i32>().unwrap(), data);
    }

    #[test]
    fn shape_mismatch_rejected() {
        assert!(f32_lit(&[2, 2], &[1.0, 2.0]).is_err());
        assert!(i32_lit(&[3], &[1, 2]).is_err());
    }

    #[test]
    fn scalars() {
        assert_eq!(to_i32_scalar(&i32_scalar(-7)).unwrap(), -7);
        assert_eq!(u32_scalar(5).get_first_element::<u32>().unwrap(), 5);
    }
}
