//! LoGra: LLM-scale data valuation with influence functions.
//!
//! Rust coordinator (L3) of the three-layer reproduction of Choe et al.,
//! "What is Your Data Worth to GPT?" (NeurIPS 2025). See DESIGN.md for the
//! system inventory and experiment index.

pub mod baselines;
pub mod cli;
pub mod coordinator;
pub mod config;
pub mod data;
pub mod eval;
pub mod linalg;
pub mod runtime;
pub mod store;
pub mod hessian;
pub mod model;
pub mod util;
pub mod valuation;
