//! LoGra: LLM-scale data valuation with influence functions.
//!
//! Rust coordinator (L3) of the three-layer reproduction of Choe et al.,
//! "What is Your Data Worth to GPT?" (NeurIPS 2025). See DESIGN.md for the
//! system inventory and experiment index.
//!
//! # Quickstart: one-call valuation with [`valuation::Valuator`]
//!
//! The query side has ONE public seam: [`valuation::Valuator`] opens a
//! gradient-store fabric (v1, sharded, or quantized — the codec is
//! auto-detected from `shards.json`), resolves [`valuation::Backend::Auto`]
//! to a concrete [`valuation::ScanBackend`], validates the configuration
//! with typed [`valuation::ValuationError`]s, and answers
//! `query` / `query_async` / `query_batch` requests whose `topk` and
//! [`valuation::Normalization`] are set per call:
//!
//! ```
//! use std::sync::Arc;
//! use logra::hessian::BlockHessian;
//! use logra::store::GradStoreWriter;
//! use logra::valuation::{Backend, Normalization, QueryRequest, Valuator};
//!
//! # fn main() -> anyhow::Result<()> {
//! // A tiny store: 3 projected "gradient" rows of width 4.
//! let dir = std::env::temp_dir().join("logra-doc-quickstart");
//! let _ = std::fs::remove_dir_all(&dir);
//! let k = 4;
//! let rows: Vec<f32> = vec![
//!     1.0, 0.0, 0.0, 0.0, //
//!     0.0, 1.0, 0.0, 0.0, //
//!     0.9, 0.1, 0.0, 0.0, //
//! ];
//! let mut w = GradStoreWriter::create(&dir, k)?;
//! w.append(&[10, 11, 12], &rows)?;
//! w.finalize()?;
//!
//! // Fit the projected Fisher from the stored rows, open the fabric, ask
//! // which stored rows are most valuable for a query gradient.
//! let mut hess = BlockHessian::single_block(k);
//! hess.accumulate(&rows, 3);
//! let valuator = Valuator::open(&dir)?
//!     .backend(Backend::Auto)
//!     .preconditioner(Arc::new(hess.preconditioner(0.1)?))
//!     .normalization(Normalization::RelatIf)
//!     .build()?;
//! let results = valuator.query(QueryRequest::gradients(vec![1.0, 0.0, 0.0, 0.0], 1, 2))?;
//! let top_ids: Vec<u64> = results[0].top.iter().map(|&(_, id)| id).collect();
//! assert_eq!(top_ids.len(), 2);
//! assert_eq!(top_ids[0], 10); // the aligned row wins
//! # Ok(()) }
//! ```
//!
//! The same call shape serves a sharded fabric (parallel scan-and-merge)
//! and a quantized one (int8 coarse scan + exact rescore) — `Auto` picks
//! the backend from the store; results are bit-identical to the
//! sequential scan wherever exactness applies.
//!
//! # Multi-stage sessions
//!
//! [`session::Session`] opens SEVERAL stores (checkpoints, or pretrain +
//! finetune stages) from one `session.json` manifest and fans a single
//! query out to all of them over ONE shared scan pool, merging per-stage
//! top-k into combined rankings. Note the normalization constraint:
//! [`session::Combine::WeightedSum`] adds raw per-stage scores, which is
//! only meaningful when every stage shares one normalization (all `none`
//! or all `relatif`) — mixing raw influence with ℓ-RelatIF scores puts
//! the addends on incompatible scales, so `Session::open` rejects that
//! combination; use Borda rank aggregation (scale-free) or
//! [`session::Combine::PerStageOnly`] for mixed-norm sessions.

pub mod baselines;
pub mod cli;
pub mod coordinator;
pub mod config;
pub mod data;
pub mod eval;
pub mod linalg;
pub mod runtime;
pub mod serve;
pub mod session;
pub mod store;
pub mod hessian;
pub mod model;
pub mod obs;
pub mod util;
pub mod valuation;
