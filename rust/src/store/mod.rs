//! On-disk gradient store: mmap substrate, append-only store format,
//! background writer. The paper's "write projected gradients once, scan
//! forever" storage layer (§2, §4.2, §E.2).

pub mod grad_store;
pub mod mmap;
pub mod writer_thread;

pub use grad_store::{GradStore, GradStoreWriter};
pub use mmap::Mmap;
pub use writer_thread::BackgroundWriter;
