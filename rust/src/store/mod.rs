//! On-disk gradient store: mmap substrate, append-only store format,
//! background writer, sharded multi-writer fabric. The paper's "write
//! projected gradients once, scan forever" storage layer (§2, §4.2, §E.2).
//!
//! # Store format
//!
//! A **v1 store** is a directory with two files:
//!
//! ```text
//! <dir>/grads.bin   header(32B) + rows * k * f32 (row-major)
//! <dir>/ids.bin     rows * u64 data-ids
//! ```
//!
//! The `grads.bin` header is `magic "LOGRAGRD", u32 version, u32 k,
//! u64 rows, 8B pad`; the writer's `finalize` patches the row count, so a
//! crash mid-write leaves a store reporting the last durable count.
//!
//! A **sharded store** is a directory holding a `shards.json` manifest
//! plus one v1 store per `shard-NNNN/` subdirectory:
//!
//! ```text
//! <dir>/shards.json          {"version", "k", "shards": [{"dir","rows"}...], "offsets"}
//! <dir>/shard-0000/grads.bin
//! <dir>/shard-0000/ids.bin
//! <dir>/shard-0001/...
//! ```
//!
//! Global row order is the concatenation of shards in manifest order.
//! Manifest row counts are advisory; each shard's own header is the
//! durability authority, which makes per-shard finalization (one writer
//! thread per shard) crash-consistent without cross-shard coordination.
//! Directories without `shards.json` open as 1-shard fabrics, so the v1
//! layout keeps working everywhere.
//!
//! A **quantized (v2) store** replaces each shard's f32 rows with symmetric
//! int8 codes plus per-64-value-block f32 scales (`codes.bin` +
//! `scales.bin` + `ids.bin`, manifest `"codec": "int8"`) — ~4x smaller and
//! ~4x less scan bandwidth; see [`quant`] and the two-stage query engine
//! in `valuation::twostage`.
//!
//! An **IVF index** (`logra store index`) adds per-shard
//! `centroids.bin` + `lists.bin` files next to the codes and an
//! `"index": "ivf"` manifest field, giving queries a sublinear stage-0
//! candidate generator; see [`ivf`] and the IVF engine in
//! `valuation::ann`. Manifests without the field parse unchanged.
//!
//! # Live growth
//!
//! The manifest carries a monotonic `"generation"` counter, bumped on
//! every publication (initial finalize, `logra store append`, incremental
//! quantize, index build). Writers finalize new `shard-NNNN/` directories
//! *before* publishing the manifest via write-temp + fsync + atomic
//! rename, so a reader always loads either the previous generation intact
//! or the new one completely — never a blend. Manifests written before
//! the field existed parse as generation 0. See [`generation`] for the
//! append/snapshot-slot machinery and [`fault`] for the `LOGRA_FAULT`
//! injection layer that the crash-consistency tests drive.

pub mod fault;
pub mod generation;
pub mod grad_store;
pub mod ivf;
pub mod mmap;
pub mod quant;
pub mod shards;
pub mod writer_thread;

pub use generation::{append_shard, current_generation, AppendReport, Slot};
pub use grad_store::{GradStore, GradStoreWriter};
pub use ivf::{
    build_index, build_index_incremental, IvfBuildReport, IvfIncrementalReport, IvfIndex,
    IvfShard, IVF_CENTROIDS_FILE, IVF_INDEX_NAME, IVF_LISTS_FILE,
};
pub use mmap::Mmap;
pub use quant::{
    quantize_store, quantize_store_incremental, QuantShardedStore, QuantStore, QuantWriter,
    QuantizeReport, QUANT_BLOCK, QUANT_CODES_FILE,
};
pub use shards::{
    merge_store, shard_store, stat_store, ShardBytes, ShardManifest, ShardWriter,
    ShardedStore, ShardedWriter, StoreCodec, StoreStat, SHARD_MANIFEST,
};
pub use writer_thread::BackgroundWriter;
