//! Read-only memory-mapped file (libc wrapper; no memmap2 offline).
//!
//! The gradient store's read path — the paper's §E.2 design point: stored
//! projected gradients are scanned strictly sequentially per query, so a
//! page-cache-backed mapping plus `MADV_SEQUENTIAL` beats explicit reads
//! (no user-space copy, kernel readahead does the prefetch).

use std::fs::File;
use std::os::unix::io::AsRawFd;
use std::path::Path;

use anyhow::{anyhow, Context, Result};

/// Read-only mapping of an entire file.
pub struct Mmap {
    ptr: *mut libc::c_void,
    len: usize,
    // Keep the file open for the mapping's lifetime (not strictly needed
    // on Linux, but makes the ownership story explicit).
    _file: File,
}

// The mapping is read-only and the underlying pages are immutable for the
// store's lifetime; sharing across threads is safe.
unsafe impl Send for Mmap {}
unsafe impl Sync for Mmap {}

impl Mmap {
    pub fn open(path: &Path) -> Result<Self> {
        let file = File::open(path).with_context(|| format!("open {}", path.display()))?;
        let len = file.metadata()?.len() as usize;
        if len == 0 {
            return Ok(Mmap { ptr: std::ptr::null_mut(), len: 0, _file: file });
        }
        let ptr = unsafe {
            libc::mmap(
                std::ptr::null_mut(),
                len,
                libc::PROT_READ,
                libc::MAP_SHARED,
                file.as_raw_fd(),
                0,
            )
        };
        if ptr == libc::MAP_FAILED {
            return Err(anyhow!("mmap {} failed: {}", path.display(), std::io::Error::last_os_error()));
        }
        Ok(Mmap { ptr, len, _file: file })
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn as_slice(&self) -> &[u8] {
        if self.len == 0 {
            return &[];
        }
        unsafe { std::slice::from_raw_parts(self.ptr as *const u8, self.len) }
    }

    /// Hint sequential access (enables aggressive kernel readahead).
    pub fn advise_sequential(&self) {
        if self.len > 0 {
            unsafe {
                libc::madvise(self.ptr, self.len, libc::MADV_SEQUENTIAL);
            }
        }
    }

    /// Hint that a byte range will be needed soon (explicit prefetch).
    pub fn advise_willneed(&self, offset: usize, len: usize) {
        if self.len == 0 || offset >= self.len {
            return;
        }
        let len = len.min(self.len - offset);
        // madvise needs page alignment for the start address.
        let page = 4096usize;
        let aligned = offset & !(page - 1);
        let adj_len = len + (offset - aligned);
        unsafe {
            libc::madvise(
                (self.ptr as usize + aligned) as *mut libc::c_void,
                adj_len,
                libc::MADV_WILLNEED,
            );
        }
    }
}

impl Drop for Mmap {
    fn drop(&mut self) {
        if self.len > 0 {
            unsafe {
                libc::munmap(self.ptr, self.len);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn tmpfile(name: &str, contents: &[u8]) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("logra-mmap-tests");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(name);
        let mut f = File::create(&path).unwrap();
        f.write_all(contents).unwrap();
        path
    }

    #[test]
    fn maps_file_contents() {
        let path = tmpfile("a.bin", b"hello mmap");
        let m = Mmap::open(&path).unwrap();
        assert_eq!(m.as_slice(), b"hello mmap");
        m.advise_sequential();
        m.advise_willneed(0, 4);
    }

    #[test]
    fn empty_file_ok() {
        let path = tmpfile("empty.bin", b"");
        let m = Mmap::open(&path).unwrap();
        assert!(m.is_empty());
        assert_eq!(m.as_slice(), b"");
    }

    #[test]
    fn missing_file_is_error() {
        assert!(Mmap::open(Path::new("/nonexistent/xyz.bin")).is_err());
    }

    #[test]
    fn willneed_out_of_range_is_noop() {
        let path = tmpfile("b.bin", &[0u8; 8192]);
        let m = Mmap::open(&path).unwrap();
        m.advise_willneed(9000, 100);
        m.advise_willneed(4000, 100000);
    }
}
