//! On-disk projected-gradient store.
//!
//! The heart of the paper's cost trade (§4.2): write projected gradients
//! for ALL training data to disk once, then answer every future influence
//! query by scanning them — no gradient recomputation. Layout:
//!
//!   <dir>/grads.bin   header(32B) + rows * k * f32 (row-major)
//!   <dir>/ids.bin     rows * u64 data-ids (the LogIX `data_id` concept)
//!
//! Header: magic "LOGRAGRD", u32 version, u32 k, u64 row count, 8B pad.
//! Reads go through a read-only mmap ([`Mmap`]); writes through a buffered
//! appender whose `finalize` patches the row count, so a crash mid-write
//! leaves a store that reports the last durable count.

use std::fs::{File, OpenOptions};
use std::io::{BufWriter, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use super::mmap::Mmap;

const MAGIC: &[u8; 8] = b"LOGRAGRD";
const VERSION: u32 = 1;
const HEADER_LEN: usize = 32;

fn header_bytes(k: u32, rows: u64) -> [u8; HEADER_LEN] {
    let mut h = [0u8; HEADER_LEN];
    h[..8].copy_from_slice(MAGIC);
    h[8..12].copy_from_slice(&VERSION.to_le_bytes());
    h[12..16].copy_from_slice(&k.to_le_bytes());
    h[16..24].copy_from_slice(&rows.to_le_bytes());
    h
}

/// Append-only writer. One writer per store directory.
pub struct GradStoreWriter {
    grads: BufWriter<File>,
    ids: BufWriter<File>,
    dir: PathBuf,
    k: usize,
    rows: u64,
}

impl GradStoreWriter {
    pub fn create(dir: &Path, k: usize) -> Result<Self> {
        if k == 0 {
            return Err(anyhow!("grad store needs k > 0"));
        }
        std::fs::create_dir_all(dir)?;
        let gpath = dir.join("grads.bin");
        let ipath = dir.join("ids.bin");
        let mut gf = BufWriter::new(File::create(&gpath)?);
        gf.write_all(&header_bytes(k as u32, 0))?;
        let ifile = BufWriter::new(File::create(&ipath)?);
        Ok(GradStoreWriter { grads: gf, ids: ifile, dir: dir.to_path_buf(), k, rows: 0 })
    }

    /// Append a batch: `rows` is row-major [n, k]; `ids` are the n data ids.
    pub fn append(&mut self, ids: &[u64], rows: &[f32]) -> Result<()> {
        if rows.len() != ids.len() * self.k {
            return Err(anyhow!(
                "append: {} ids x k={} needs {} floats, got {}",
                ids.len(),
                self.k,
                ids.len() * self.k,
                rows.len()
            ));
        }
        let bytes = unsafe {
            std::slice::from_raw_parts(rows.as_ptr() as *const u8, rows.len() * 4)
        };
        self.grads.write_all(bytes)?;
        for &id in ids {
            self.ids.write_all(&id.to_le_bytes())?;
        }
        self.rows += ids.len() as u64;
        Ok(())
    }

    pub fn rows(&self) -> u64 {
        self.rows
    }

    /// Flush buffers and patch the header row count.
    pub fn finalize(mut self) -> Result<u64> {
        self.grads.flush()?;
        self.ids.flush()?;
        let mut f = OpenOptions::new().write(true).open(self.dir.join("grads.bin"))?;
        f.seek(SeekFrom::Start(0))?;
        f.write_all(&header_bytes(self.k as u32, self.rows))?;
        f.sync_all()?;
        // Fault point: a crash that persists the patched header but loses
        // tail data pages leaves a shard whose header over-claims — the
        // torn state `GradStore::open`'s length check must catch and the
        // quarantine path must contain.
        if super::fault::maybe_truncate("finalize_truncate", &self.dir.join("grads.bin")) {
            return Err(anyhow!(
                "fault injected: finalize_truncate in {}",
                self.dir.display()
            ));
        }
        Ok(self.rows)
    }
}

/// Read view over a finalized store.
pub struct GradStore {
    map: Mmap,
    ids_map: Mmap,
    k: usize,
    rows: usize,
}

impl GradStore {
    pub fn open(dir: &Path) -> Result<Self> {
        let map = Mmap::open(&dir.join("grads.bin"))
            .with_context(|| format!("grad store {}", dir.display()))?;
        let bytes = map.as_slice();
        if bytes.len() < HEADER_LEN || &bytes[..8] != MAGIC {
            return Err(anyhow!("bad grad store header in {}", dir.display()));
        }
        let version = u32::from_le_bytes(bytes[8..12].try_into().unwrap());
        if version != VERSION {
            return Err(anyhow!("grad store version {version} unsupported"));
        }
        let k = u32::from_le_bytes(bytes[12..16].try_into().unwrap()) as usize;
        if k == 0 {
            // A zero-k header would "open" fine and only blow up later
            // (empty chunks, divide-by-zero row math) — reject it here.
            return Err(anyhow!(
                "grad store {} header declares k=0 (corrupt or wrong file)",
                dir.display()
            ));
        }
        let rows = u64::from_le_bytes(bytes[16..24].try_into().unwrap()) as usize;
        let need = HEADER_LEN + rows * k * 4;
        if bytes.len() < need {
            return Err(anyhow!(
                "grad store truncated: need {need} bytes, have {}",
                bytes.len()
            ));
        }
        let ids_map = Mmap::open(&dir.join("ids.bin"))?;
        if ids_map.len() < rows * 8 {
            return Err(anyhow!(
                "ids file truncated: {rows} rows need {} bytes, have {}",
                rows * 8,
                ids_map.len()
            ));
        }
        map.advise_sequential();
        Ok(GradStore { map, ids_map, k, rows })
    }

    pub fn k(&self) -> usize {
        self.k
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Raw f32 view of rows [start, start+len).
    pub fn chunk(&self, start: usize, len: usize) -> &[f32] {
        assert!(start + len <= self.rows, "chunk out of range");
        let byte_off = HEADER_LEN + start * self.k * 4;
        let bytes = &self.map.as_slice()[byte_off..byte_off + len * self.k * 4];
        // The writer produced these bytes from f32s on this machine;
        // alignment holds because HEADER_LEN and k*4 are 4-byte multiples.
        unsafe {
            std::slice::from_raw_parts(bytes.as_ptr() as *const f32, len * self.k)
        }
    }

    /// One row.
    pub fn row(&self, i: usize) -> &[f32] {
        self.chunk(i, 1)
    }

    /// Data id of row i.
    pub fn id(&self, i: usize) -> u64 {
        assert!(i < self.rows);
        let b = &self.ids_map.as_slice()[i * 8..i * 8 + 8];
        u64::from_le_bytes(b.try_into().unwrap())
    }

    /// Prefetch hint for rows [start, start+len) (overlap IO with compute).
    pub fn prefetch(&self, start: usize, len: usize) {
        let byte_off = HEADER_LEN + start * self.k * 4;
        self.map.advise_willneed(byte_off, len * self.k * 4);
    }

    /// Total stored bytes (Table-1 "Storage" column).
    pub fn storage_bytes(&self) -> u64 {
        (self.map.len() + self.ids_map.len()) as u64
    }

    /// Bytes of `grads.bin` (header + f32 rows) — the `store stat`
    /// per-component breakdown.
    pub fn grads_bytes(&self) -> u64 {
        self.map.len() as u64
    }

    /// Bytes of `ids.bin`.
    pub fn ids_bytes(&self) -> u64 {
        self.ids_map.len() as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn tmpdir(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("logra-store-tests").join(name);
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn roundtrip_batches() {
        let dir = tmpdir("roundtrip");
        let k = 6;
        let mut w = GradStoreWriter::create(&dir, k).unwrap();
        let mut rng = Pcg32::seeded(1);
        let mut all_rows: Vec<f32> = Vec::new();
        let mut all_ids: Vec<u64> = Vec::new();
        let mut next_id = 100u64;
        for _ in 0..7 {
            let n = 1 + rng.below_usize(5);
            let ids: Vec<u64> = (0..n).map(|i| next_id + i as u64).collect();
            next_id += n as u64;
            let mut rows = vec![0.0f32; n * k];
            rng.fill_normal(&mut rows, 1.0);
            w.append(&ids, &rows).unwrap();
            all_rows.extend_from_slice(&rows);
            all_ids.extend_from_slice(&ids);
        }
        let total = w.finalize().unwrap();
        assert_eq!(total as usize, all_ids.len());

        let s = GradStore::open(&dir).unwrap();
        assert_eq!(s.rows(), all_ids.len());
        assert_eq!(s.k(), k);
        assert_eq!(s.chunk(0, s.rows()), &all_rows[..]);
        for i in 0..s.rows() {
            assert_eq!(s.id(i), all_ids[i]);
            assert_eq!(s.row(i), &all_rows[i * k..(i + 1) * k]);
        }
        s.prefetch(0, s.rows());
        assert!(s.storage_bytes() > (all_rows.len() * 4) as u64);
    }

    #[test]
    fn property_chunk_views_consistent() {
        crate::util::proptest::check("store-chunks", 10, |g| {
            let dir = tmpdir(&format!("prop{}", g.rng.next_u32()));
            let k = 1 + g.int_in(0, 16);
            let n = 1 + g.int_in(0, 64);
            let mut w = GradStoreWriter::create(&dir, k).unwrap();
            let mut rows = vec![0.0f32; n * k];
            g.rng.fill_normal(&mut rows, 1.0);
            let ids: Vec<u64> = (0..n as u64).collect();
            // Split the append into arbitrary batch boundaries.
            let mut start = 0usize;
            while start < n {
                let len = 1 + g.rng.below_usize(n - start);
                w.append(&ids[start..start + len], &rows[start * k..(start + len) * k])
                    .unwrap();
                start += len;
            }
            w.finalize().unwrap();
            let s = GradStore::open(&dir).unwrap();
            crate::prop_assert!(s.rows() == n, "rows {} != {n}", s.rows());
            // Any chunk decomposition reproduces the same bytes.
            let mut at = 0usize;
            while at < n {
                let len = 1 + g.rng.below_usize(n - at);
                let got = s.chunk(at, len);
                crate::prop_assert!(
                    got == &rows[at * k..(at + len) * k],
                    "chunk mismatch at {at}+{len}"
                );
                at += len;
            }
            Ok(())
        });
    }

    #[test]
    fn append_shape_mismatch_rejected() {
        let dir = tmpdir("mismatch");
        let mut w = GradStoreWriter::create(&dir, 4).unwrap();
        assert!(w.append(&[1, 2], &[0.0; 7]).is_err());
    }

    #[test]
    fn unfinalized_store_reports_zero_rows() {
        let dir = tmpdir("unfinalized");
        let mut w = GradStoreWriter::create(&dir, 3).unwrap();
        w.append(&[1], &[1.0, 2.0, 3.0]).unwrap();
        // Flush data but never finalize: header still says 0 rows.
        drop(w);
        let s = GradStore::open(&dir).unwrap();
        assert_eq!(s.rows(), 0);
    }

    #[test]
    fn corrupt_magic_rejected() {
        let dir = tmpdir("corrupt");
        std::fs::write(dir.join("grads.bin"), b"NOTMAGICxxxxxxxxxxxxxxxxxxxxxxxxxxx")
            .unwrap();
        std::fs::write(dir.join("ids.bin"), b"").unwrap();
        assert!(GradStore::open(&dir).is_err());
    }

    #[test]
    fn zero_k_header_rejected() {
        let dir = tmpdir("zero-k");
        // Hand-built header: valid magic/version, k=0, 5 rows.
        std::fs::write(dir.join("grads.bin"), header_bytes(0, 5)).unwrap();
        std::fs::write(dir.join("ids.bin"), vec![0u8; 5 * 8]).unwrap();
        let err = GradStore::open(&dir).unwrap_err().to_string();
        assert!(err.contains("k=0"), "unexpected error: {err}");
        // And the writer refuses to produce such a store in the first place.
        assert!(GradStoreWriter::create(&tmpdir("zero-k-create"), 0).is_err());
    }

    #[test]
    fn short_ids_file_rejected() {
        let dir = tmpdir("short-ids");
        let k = 4;
        let mut w = GradStoreWriter::create(&dir, k).unwrap();
        let ids: Vec<u64> = (0..6).collect();
        let rows = vec![0.5f32; 6 * k];
        w.append(&ids, &rows).unwrap();
        w.finalize().unwrap();
        // Corrupt: drop the tail of ids.bin below the declared row count.
        let f = OpenOptions::new().write(true).open(dir.join("ids.bin")).unwrap();
        f.set_len(3 * 8).unwrap();
        let err = GradStore::open(&dir).unwrap_err().to_string();
        assert!(err.contains("ids file truncated"), "unexpected error: {err}");
    }
}
