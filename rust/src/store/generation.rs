//! Live corpus growth: generation probes, shard appends, and the
//! snapshot slot that lets `logra serve` swap fabrics under load.
//!
//! The contract, end to end:
//!
//! 1. A writer appends a new `shard-NNNN/` directory next to the existing
//!    shards and finalizes it through the crash-consistent
//!    [`GradStoreWriter`] path (data flushed, header patched last,
//!    `sync_all`). Until the manifest mentions the shard, it is invisible
//!    to every reader — a crash here leaves the store exactly as it was.
//! 2. The writer publishes a new manifest with `generation + 1` via
//!    write-temp + fsync + atomic rename ([`ShardManifest::save`]), so a
//!    concurrent reader loads either the old manifest or the new one,
//!    never a torn blend.
//! 3. Readers that want a consistent view pin an `Arc` snapshot from a
//!    [`Slot`] once per query; a reload stores a new `Arc` and in-flight
//!    queries keep scanning the generation they admitted under.
//!
//! [`GradStoreWriter`]: super::GradStoreWriter
//! [`ShardManifest::save`]: super::ShardManifest::save

use std::path::Path;
use std::sync::{Arc, RwLock};

use anyhow::{bail, Context, Result};

use super::{GradStoreWriter, ShardManifest, StoreCodec, SHARD_MANIFEST};

/// Minimal ArcSwap-style slot, std-only: readers clone the current `Arc`
/// under a briefly-held read lock, writers swap the pointer under the
/// write lock. Clones taken before a [`store`](Slot::store) keep the old
/// value alive for as long as they need it — that is the snapshot pin.
pub struct Slot<T> {
    inner: RwLock<Arc<T>>,
}

impl<T> Slot<T> {
    pub fn new(value: Arc<T>) -> Self {
        Slot {
            inner: RwLock::new(value),
        }
    }

    /// Pin the current snapshot.
    pub fn load(&self) -> Arc<T> {
        self.inner
            .read()
            .unwrap_or_else(|e| e.into_inner())
            .clone()
    }

    /// Publish a new snapshot; existing pins are unaffected.
    pub fn store(&self, value: Arc<T>) {
        *self.inner.write().unwrap_or_else(|e| e.into_inner()) = value;
    }
}

/// Cheap manifest probe: the published generation of `dir`, without
/// opening any shard. Legacy single-store directories (no manifest)
/// report generation 0 and never advance.
pub fn current_generation(dir: &Path) -> Result<u64> {
    if !dir.join(SHARD_MANIFEST).is_file() {
        return Ok(0);
    }
    Ok(ShardManifest::load(dir)?.generation)
}

/// What [`append_shard`] published.
#[derive(Debug)]
pub struct AppendReport {
    /// Directory name of the new shard (e.g. `shard-0004`).
    pub shard_dir: String,
    /// Rows in the new shard.
    pub rows: u64,
    /// Generation the store now serves.
    pub generation: u64,
}

/// Append one finalized shard to a sharded f32 store and publish it as
/// the next generation. `rows.len()` must equal `ids.len() * k`.
///
/// The shard is written and finalized *before* the manifest is touched,
/// so a crash at any point leaves the previous generation fully
/// servable; a leftover directory from an earlier torn publish is
/// removed and rewritten.
pub fn append_shard(dir: &Path, ids: &[u64], rows: &[f32]) -> Result<AppendReport> {
    let mut man = ShardManifest::load(dir)
        .with_context(|| format!("append requires a shard manifest in {}", dir.display()))?;
    if man.codec != StoreCodec::F32 {
        bail!(
            "append targets the f32 fabric; {} is {} — append to its source store, \
             then run `store quantize --incremental`",
            dir.display(),
            man.codec.as_str()
        );
    }
    if ids.is_empty() {
        bail!("append of zero rows");
    }
    if rows.len() != ids.len() * man.k {
        bail!(
            "append shape mismatch: {} ids x k={} needs {} floats, got {}",
            ids.len(),
            man.k,
            ids.len() * man.k,
            rows.len()
        );
    }

    // Pick the first shard-NNNN name not already claimed by the manifest.
    // An on-disk directory with that name can only be debris from a
    // publish that never happened — safe to clear.
    let mut idx = man.shard_dirs.len();
    let name = loop {
        let candidate = super::shards::shard_dir_name(idx);
        if !man.shard_dirs.iter().any(|d| d == &candidate) {
            break candidate;
        }
        idx += 1;
    };
    let shard_dir = dir.join(&name);
    if shard_dir.exists() {
        std::fs::remove_dir_all(&shard_dir)
            .with_context(|| format!("clear stale shard dir {}", shard_dir.display()))?;
    }

    let mut w = GradStoreWriter::create(&shard_dir, man.k)?;
    w.append(ids, rows)?;
    let n = w.finalize()?;

    man.shard_dirs.push(name.clone());
    man.shard_rows.push(n);
    man.generation += 1;
    man.save(dir)?;
    Ok(AppendReport {
        shard_dir: name,
        rows: n,
        generation: man.generation,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::store::ShardedStore;

    fn tmpdir(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("logra-gen-{name}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn seed_store(dir: &Path, k: usize, shards: usize, rows_per: usize) {
        let mut w = crate::store::ShardedWriter::create(dir, k, shards).unwrap();
        for s in 0..shards {
            for r in 0..rows_per {
                let id = (s * rows_per + r) as u64;
                let row: Vec<f32> = (0..k).map(|j| (id as f32) + j as f32 * 0.5).collect();
                w.append_shard(s, &[id], &row).unwrap();
            }
        }
        w.finalize().unwrap();
    }

    #[test]
    fn slot_pins_survive_swap() {
        let slot = Slot::new(Arc::new(1u64));
        let pinned = slot.load();
        slot.store(Arc::new(2u64));
        assert_eq!(*pinned, 1, "pre-swap pin must keep old snapshot");
        assert_eq!(*slot.load(), 2);
    }

    #[test]
    fn append_publishes_next_generation() {
        let dir = tmpdir("append");
        seed_store(&dir, 4, 2, 3);
        assert_eq!(current_generation(&dir).unwrap(), 1);

        let ids = [6u64, 7];
        let rows: Vec<f32> = (0..8).map(|v| v as f32).collect();
        let rep = append_shard(&dir, &ids, &rows).unwrap();
        assert_eq!(rep.shard_dir, "shard-0002");
        assert_eq!(rep.rows, 2);
        assert_eq!(rep.generation, 2);
        assert_eq!(current_generation(&dir).unwrap(), 2);

        let store = ShardedStore::open(&dir).unwrap();
        assert_eq!(store.rows(), 8);
        assert_eq!(store.id(6), 6);
        assert_eq!(store.row(7), &rows[4..8]);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn append_rejects_shape_and_codec_errors() {
        let dir = tmpdir("append-rej");
        seed_store(&dir, 4, 1, 2);
        let err = append_shard(&dir, &[9], &[0.0; 3]).unwrap_err().to_string();
        assert!(err.contains("shape mismatch"), "got: {err}");
        assert!(append_shard(&dir, &[], &[]).is_err());
        // Generation untouched by rejected appends.
        assert_eq!(current_generation(&dir).unwrap(), 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn legacy_dir_probes_generation_zero() {
        let dir = tmpdir("legacy-probe");
        let mut w = GradStoreWriter::create(&dir, 4).unwrap();
        w.append(&[0], &[0.0; 4]).unwrap();
        w.finalize().unwrap();
        assert_eq!(current_generation(&dir).unwrap(), 0);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
