//! Background store writer: overlaps gradient disk writes with the next
//! batch's PJRT execution (the paper's §E.2 logging-phase overlap,
//! implemented with a bounded pipeline instead of Python multiprocessing).
//!
//! Durability errors on the writer thread — including faults injected via
//! [`super::fault`] into the finalize path — are captured and re-raised
//! from [`BackgroundWriter::finish`], never swallowed: a caller that gets
//! `Ok` from `finish` holds a fully finalized, reopenable shard, which is
//! the invariant the live-growth publish step builds on.

use std::path::Path;
use std::thread::JoinHandle;

use anyhow::{anyhow, Result};

use crate::util::pipeline::{bounded, Sender};

use super::grad_store::GradStoreWriter;

/// One logging batch headed for disk.
pub struct WriteJob {
    pub ids: Vec<u64>,
    pub rows: Vec<f32>,
}

/// Handle to the background writer.
pub struct BackgroundWriter {
    tx: Option<Sender<WriteJob>>,
    handle: Option<JoinHandle<Result<u64>>>,
}

impl BackgroundWriter {
    /// Spawn a writer thread appending to a fresh store at `dir`.
    /// `queue_cap` bounds in-flight batches (backpressure toward the
    /// executor if the disk falls behind).
    pub fn spawn(dir: &Path, k: usize, queue_cap: usize) -> Result<Self> {
        let mut writer = GradStoreWriter::create(dir, k)?;
        let (tx, rx) = bounded::<WriteJob>(queue_cap);
        let handle = std::thread::Builder::new()
            .name("grad-store-writer".into())
            .spawn(move || -> Result<u64> {
                while let Some(job) = rx.recv() {
                    writer.append(&job.ids, &job.rows)?;
                }
                writer.finalize()
            })?;
        Ok(BackgroundWriter { tx: Some(tx), handle: Some(handle) })
    }

    /// Queue a batch (blocks when the queue is full).
    pub fn submit(&self, ids: Vec<u64>, rows: Vec<f32>) -> Result<()> {
        self.tx
            .as_ref()
            .expect("writer already closed")
            .send(WriteJob { ids, rows })
            .map_err(|_| anyhow!("store writer thread died"))
    }

    /// Close the queue, join the thread, return the final row count.
    pub fn finish(mut self) -> Result<u64> {
        drop(self.tx.take());
        self.handle
            .take()
            .expect("already finished")
            .join()
            .map_err(|_| anyhow!("store writer panicked"))?
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::store::grad_store::GradStore;
    use crate::util::rng::Pcg32;

    #[test]
    fn background_writes_match_foreground() {
        let dir = std::env::temp_dir().join("logra-store-tests").join("bg");
        let _ = std::fs::remove_dir_all(&dir);
        let k = 5;
        let w = BackgroundWriter::spawn(&dir, k, 2).unwrap();
        let mut rng = Pcg32::seeded(3);
        let mut want: Vec<f32> = Vec::new();
        for b in 0..20u64 {
            let n = 3;
            let ids: Vec<u64> = (b * 3..b * 3 + 3).collect();
            let mut rows = vec![0.0f32; n * k];
            rng.fill_normal(&mut rows, 1.0);
            want.extend_from_slice(&rows);
            w.submit(ids, rows).unwrap();
        }
        let total = w.finish().unwrap();
        assert_eq!(total, 60);
        let s = GradStore::open(&dir).unwrap();
        assert_eq!(s.rows(), 60);
        assert_eq!(s.chunk(0, 60), &want[..]);
        assert_eq!(s.id(59), 59);
    }

    #[test]
    fn finalize_fault_surfaces_through_finish() {
        // Path-filtered arm: fault state is process-global, the filter
        // keeps concurrently running tests out of the blast radius.
        let dir = std::env::temp_dir().join("logra-store-tests").join("bg-fault");
        let _ = std::fs::remove_dir_all(&dir);
        let _x = crate::store::fault::exclusive();
        let w = BackgroundWriter::spawn(&dir, 4, 2).unwrap();
        w.submit(vec![0, 1], vec![0.5; 8]).unwrap();
        crate::store::fault::arm("finalize_truncate=bg-fault");
        let err = w.finish();
        crate::store::fault::disarm();
        let msg = err.unwrap_err().to_string();
        assert!(msg.contains("fault injected"), "got: {msg}");
    }
}
