//! Sharded gradient store: a store directory becomes a set of shard
//! subdirectories plus a JSON shard manifest, so extraction can run one
//! writer per thread and queries can scan shards in parallel.
//!
//! Layout:
//!
//!   <dir>/shards.json          manifest: version, k, shard dirs + rows
//!   <dir>/shard-0000/grads.bin ordinary v1 [`GradStore`] files
//!   <dir>/shard-0000/ids.bin
//!   <dir>/shard-0001/...
//!
//! Global row order is the concatenation of shards in manifest order;
//! global row g lives at shard s, local row g - offset(s).
//!
//! Crash consistency: the manifest is written (atomically, via temp file +
//! fsync + rename) at creation time with zero row counts, and each shard
//! owns its durability through the v1 header-patching `finalize`. Opening
//! trusts the per-shard headers, never the manifest row counts — a crash
//! mid-extraction leaves every finalized shard intact and the unfinalized
//! shard reporting its last durable count, exactly like a v1 store.
//!
//! Live growth: the manifest carries a monotonic `generation` counter,
//! bumped on every publication. Writers append and finalize new shard
//! directories *first* (invisible until referenced), then publish the new
//! generation atomically — a reader therefore always sees either the
//! previous generation intact or the new one completely, never a blend
//! (see [`super::generation`] for the append/reload orchestration and
//! [`super::fault`] for the injection points that prove it).
//!
//! A directory without `shards.json` opens as a 1-shard fabric over the v1
//! layout, so every existing store keeps working unchanged.

use std::fs::File;
use std::io::{Read, Write};
use std::path::{Path, PathBuf};

use anyhow::{anyhow, ensure, Context, Result};

use super::grad_store::{GradStore, GradStoreWriter};

/// Manifest file name inside a sharded store directory.
pub const SHARD_MANIFEST: &str = "shards.json";

const MANIFEST_VERSION: u64 = 1;

/// On-disk row encoding of a store's shards. `F32` is the v1 layout
/// (`grads.bin` + `ids.bin`); `Int8` is the v2 quantized codec
/// ([`super::quant`]: `codes.bin` + `scales.bin` + `ids.bin`). Manifests
/// without a `codec` field parse as `F32`, so every pre-codec store keeps
/// opening unchanged.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StoreCodec {
    F32,
    Int8,
}

impl StoreCodec {
    pub fn as_str(self) -> &'static str {
        match self {
            StoreCodec::F32 => "f32",
            StoreCodec::Int8 => "int8",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "f32" => Ok(StoreCodec::F32),
            "int8" => Ok(StoreCodec::Int8),
            other => Err(anyhow!("shard manifest: unknown codec {other:?}")),
        }
    }
}

// --------------------------------------------------------------- manifest

/// Parsed `shards.json`: shard count, per-shard rows, k, codec, and
/// (derivable) global row offsets. Row counts are advisory — the per-shard
/// headers are the durability authority (see module docs).
#[derive(Clone, Debug, PartialEq)]
pub struct ShardManifest {
    pub k: usize,
    pub codec: StoreCodec,
    /// Monotonic publication counter: bumped by every writer that
    /// publishes a content change (initial finalize, shard append,
    /// incremental quantize, index build, reconcile). Readers use it to
    /// detect growth cheaply and to pin query snapshots; manifests
    /// written before live growth carry no field and parse as 0.
    pub generation: u64,
    /// Quantized stores only: path of the exact f32 source the codes were
    /// converted from — the stage-2 rescore substrate. Recorded by
    /// `quantize_store` so `Valuator::open` on a quantized directory can
    /// find its exact companion with zero codec-specific caller code.
    /// Advisory (the source may have moved); absent on f32 stores and on
    /// pre-PR5 quantized manifests.
    pub rescore_dir: Option<String>,
    /// Quantized stores only: name of the stage-0 ANN index persisted
    /// alongside the codes (`"ivf"` once `logra store index` has run —
    /// per-shard `centroids.bin` + `lists.bin`, see [`super::ivf`]).
    /// Absent on f32 stores and on pre-index manifests, which parse
    /// unchanged.
    pub index: Option<String>,
    pub shard_dirs: Vec<String>,
    pub shard_rows: Vec<u64>,
}

impl ShardManifest {
    pub fn n_shards(&self) -> usize {
        self.shard_dirs.len()
    }

    pub fn total_rows(&self) -> u64 {
        self.shard_rows.iter().sum()
    }

    /// Global row offsets: `offsets()[i]` is the first global row of shard
    /// i; a final entry holds the total.
    pub fn offsets(&self) -> Vec<u64> {
        let mut out = Vec::with_capacity(self.shard_rows.len() + 1);
        let mut acc = 0u64;
        out.push(0);
        for &r in &self.shard_rows {
            acc += r;
            out.push(acc);
        }
        out
    }

    pub fn to_json(&self) -> String {
        let mut s = String::new();
        s.push_str("{\n");
        s.push_str(&format!("  \"version\": {MANIFEST_VERSION},\n"));
        s.push_str(&format!("  \"k\": {},\n", self.k));
        s.push_str(&format!("  \"generation\": {},\n", self.generation));
        s.push_str(&format!("  \"codec\": \"{}\",\n", self.codec.as_str()));
        if let Some(rd) = &self.rescore_dir {
            s.push_str(&format!("  \"rescore_dir\": \"{rd}\",\n"));
        }
        if let Some(ix) = &self.index {
            s.push_str(&format!("  \"index\": \"{ix}\",\n"));
        }
        s.push_str("  \"shards\": [\n");
        for (i, (dir, rows)) in self.shard_dirs.iter().zip(&self.shard_rows).enumerate() {
            let comma = if i + 1 < self.shard_dirs.len() { "," } else { "" };
            s.push_str(&format!("    {{ \"dir\": \"{dir}\", \"rows\": {rows} }}{comma}\n"));
        }
        s.push_str("  ],\n");
        let offs: Vec<String> = self.offsets().iter().map(u64::to_string).collect();
        s.push_str(&format!("  \"offsets\": [{}]\n", offs.join(", ")));
        s.push_str("}\n");
        s
    }

    pub fn parse(text: &str) -> Result<Self> {
        let root = json::parse(text)?;
        let version = root
            .get("version")
            .and_then(json::Json::as_u64)
            .ok_or_else(|| anyhow!("shard manifest: missing \"version\""))?;
        ensure!(
            version == MANIFEST_VERSION,
            "shard manifest version {version} unsupported"
        );
        let k = root
            .get("k")
            .and_then(json::Json::as_u64)
            .ok_or_else(|| anyhow!("shard manifest: missing \"k\""))? as usize;
        // Pre-live-growth manifests carry no "generation": 0, never bumped
        // by anything that predates the field.
        let generation = root
            .get("generation")
            .and_then(json::Json::as_u64)
            .unwrap_or(0);
        // Pre-codec manifests (PR 1) carry no "codec" field: f32.
        let codec = match root.get("codec") {
            None => StoreCodec::F32,
            Some(v) => StoreCodec::parse(
                v.as_str().ok_or_else(|| anyhow!("shard manifest: \"codec\" must be a string"))?,
            )?,
        };
        // Optional exact-companion pointer (quantized stores, PR 5+).
        let rescore_dir = match root.get("rescore_dir") {
            None => None,
            Some(v) => Some(
                v.as_str()
                    .ok_or_else(|| anyhow!("shard manifest: \"rescore_dir\" must be a string"))?
                    .to_string(),
            ),
        };
        // Optional stage-0 index advertisement (quantized stores, PR 8+).
        let index = match root.get("index") {
            None => None,
            Some(v) => Some(
                v.as_str()
                    .ok_or_else(|| anyhow!("shard manifest: \"index\" must be a string"))?
                    .to_string(),
            ),
        };
        let shards = root
            .get("shards")
            .and_then(json::Json::as_arr)
            .ok_or_else(|| anyhow!("shard manifest: missing \"shards\" array"))?;
        let mut shard_dirs = Vec::with_capacity(shards.len());
        let mut shard_rows = Vec::with_capacity(shards.len());
        for entry in shards {
            let dir = entry
                .get("dir")
                .and_then(json::Json::as_str)
                .ok_or_else(|| anyhow!("shard manifest: shard entry missing \"dir\""))?;
            let rows = entry
                .get("rows")
                .and_then(json::Json::as_u64)
                .ok_or_else(|| anyhow!("shard manifest: shard entry missing \"rows\""))?;
            ensure!(
                !dir.contains('/') && !dir.contains("..") && !dir.is_empty(),
                "shard manifest: bad shard dir {dir:?}"
            );
            shard_dirs.push(dir.to_string());
            shard_rows.push(rows);
        }
        ensure!(!shard_dirs.is_empty(), "shard manifest: zero shards");
        Ok(ShardManifest { k, codec, generation, rescore_dir, index, shard_dirs, shard_rows })
    }

    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join(SHARD_MANIFEST);
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("read {}", path.display()))?;
        Self::parse(&text).with_context(|| format!("parse {}", path.display()))
    }

    /// Publish the manifest into `dir`: write a temp file, fsync it, then
    /// atomically rename over `shards.json` (and best-effort fsync the
    /// directory so the rename itself is durable). A crash or injected
    /// fault at any point leaves the previously published manifest
    /// untouched — readers see old or new, never a torn blend.
    pub fn save(&self, dir: &Path) -> Result<()> {
        let tmp = dir.join(".shards.json.tmp");
        {
            let mut f = File::create(&tmp)
                .with_context(|| format!("create {}", tmp.display()))?;
            f.write_all(self.to_json().as_bytes())
                .with_context(|| format!("write {}", tmp.display()))?;
            f.sync_all()
                .with_context(|| format!("fsync {}", tmp.display()))?;
        }
        // Fault points: a torn publication crashes after the temp write
        // but before the rename; a delayed one widens the race window the
        // snapshot-pinned readers must tolerate.
        super::fault::fail_point_at("manifest_tear", dir)
            .with_context(|| format!("publish {}", dir.join(SHARD_MANIFEST).display()))?;
        super::fault::delay_point("publish_delay");
        std::fs::rename(&tmp, dir.join(SHARD_MANIFEST))?;
        // Durability of the rename needs the directory entry flushed too;
        // opening a directory for fsync is Linux-specific, so tolerate
        // failure rather than gating correctness on it.
        if let Ok(d) = File::open(dir) {
            let _ = d.sync_all();
        }
        Ok(())
    }

    /// Rewrite the manifest's advisory row counts from the durable
    /// per-shard headers (used after per-thread shard finalization, where
    /// no single writer knows every count). Republishes, so the
    /// generation advances.
    pub fn reconcile(dir: &Path) -> Result<Self> {
        let mut man = Self::load(dir)?;
        for (name, rows) in man.shard_dirs.iter().zip(man.shard_rows.iter_mut()) {
            let (_, hdr_rows) = match man.codec {
                StoreCodec::F32 => read_v1_header(&dir.join(name).join("grads.bin"))?,
                StoreCodec::Int8 => {
                    super::quant::read_quant_header(&dir.join(name).join("codes.bin"))?
                }
            };
            *rows = hdr_rows;
        }
        man.generation += 1;
        man.save(dir)?;
        Ok(man)
    }
}

/// Read (k, rows) from a v1 `grads.bin` header without mapping the file.
pub(crate) fn read_v1_header(path: &Path) -> Result<(usize, u64)> {
    let mut f = File::open(path).with_context(|| format!("open {}", path.display()))?;
    let mut h = [0u8; 32];
    f.read_exact(&mut h).with_context(|| format!("header of {}", path.display()))?;
    ensure!(&h[..8] == b"LOGRAGRD", "bad grad store magic in {}", path.display());
    let k = u32::from_le_bytes(h[12..16].try_into().unwrap()) as usize;
    let rows = u64::from_le_bytes(h[16..24].try_into().unwrap());
    Ok((k, rows))
}

pub(crate) fn shard_dir_name(i: usize) -> String {
    format!("shard-{i:04}")
}

// ----------------------------------------------------------------- writer

/// Writer for one shard of a sharded store. `Send`, so extraction can move
/// each shard's writer into its own thread; `finalize` patches only this
/// shard's header (crash-consistent independently of its siblings).
pub struct ShardWriter {
    pub shard: usize,
    writer: GradStoreWriter,
}

impl ShardWriter {
    pub fn append(&mut self, ids: &[u64], rows: &[f32]) -> Result<()> {
        self.writer.append(ids, rows)
    }

    pub fn rows(&self) -> u64 {
        self.writer.rows()
    }

    /// Flush and patch this shard's header. The parent manifest keeps only
    /// advisory counts, so no cross-shard coordination is needed here; run
    /// [`ShardManifest::reconcile`] once every shard is finalized.
    pub fn finalize(self) -> Result<u64> {
        self.writer.finalize()
    }
}

/// Multi-shard writer: one [`GradStoreWriter`] per shard subdirectory.
/// Use [`ShardedWriter::append`] for single-threaded round-robin extraction
/// or [`ShardedWriter::into_shard_writers`] to fan one writer out per
/// thread.
pub struct ShardedWriter {
    dir: PathBuf,
    k: usize,
    writers: Vec<GradStoreWriter>,
    next: usize,
}

impl ShardedWriter {
    /// Create `n_shards` empty shards under `dir` and write the manifest
    /// (zero rows) so the directory is openable from the first byte.
    pub fn create(dir: &Path, k: usize, n_shards: usize) -> Result<Self> {
        ensure!(n_shards >= 1, "sharded store needs at least one shard");
        std::fs::create_dir_all(dir)?;
        let mut writers = Vec::with_capacity(n_shards);
        for i in 0..n_shards {
            writers.push(GradStoreWriter::create(&dir.join(shard_dir_name(i)), k)?);
        }
        let man = ShardManifest {
            k,
            codec: StoreCodec::F32,
            // Generation 0 = "under construction"; finalize publishes 1.
            generation: 0,
            rescore_dir: None,
            index: None,
            shard_dirs: (0..n_shards).map(shard_dir_name).collect(),
            shard_rows: vec![0; n_shards],
        };
        man.save(dir)?;
        Ok(ShardedWriter { dir: dir.to_path_buf(), k, writers, next: 0 })
    }

    pub fn n_shards(&self) -> usize {
        self.writers.len()
    }

    pub fn k(&self) -> usize {
        self.k
    }

    /// Append a batch to a specific shard.
    pub fn append_shard(&mut self, shard: usize, ids: &[u64], rows: &[f32]) -> Result<()> {
        self.writers
            .get_mut(shard)
            .ok_or_else(|| anyhow!("shard {shard} out of range"))?
            .append(ids, rows)
    }

    /// Append a batch, rotating shards round-robin per call (keeps shards
    /// balanced under single-threaded extraction).
    pub fn append(&mut self, ids: &[u64], rows: &[f32]) -> Result<()> {
        let shard = self.next;
        self.next = (self.next + 1) % self.writers.len();
        self.append_shard(shard, ids, rows)
    }

    /// Split into per-shard writers for one-writer-per-thread extraction.
    pub fn into_shard_writers(self) -> Vec<ShardWriter> {
        self.writers
            .into_iter()
            .enumerate()
            .map(|(shard, writer)| ShardWriter { shard, writer })
            .collect()
    }

    /// Finalize every shard and rewrite the manifest with final counts.
    pub fn finalize(self) -> Result<ShardManifest> {
        let dir = self.dir;
        let k = self.k;
        let mut shard_rows = Vec::with_capacity(self.writers.len());
        for w in self.writers {
            shard_rows.push(w.finalize()?);
        }
        // Publication: advance past whatever generation the in-progress
        // manifest carried (0 from `create`).
        let generation = ShardManifest::load(&dir).map(|m| m.generation).unwrap_or(0) + 1;
        let man = ShardManifest {
            k,
            codec: StoreCodec::F32,
            generation,
            rescore_dir: None,
            index: None,
            shard_dirs: (0..shard_rows.len()).map(shard_dir_name).collect(),
            shard_rows,
        };
        man.save(&dir)?;
        Ok(man)
    }
}

// ------------------------------------------------------------------ store

/// Read view over a sharded store — or over a plain v1 store, which opens
/// as a 1-shard fabric. Exposes the same `rows()/k()/chunk()/id()` contract
/// as [`GradStore`] via global→(shard, local) translation; `chunk` views
/// must not cross a shard boundary (see [`ShardedStore::contiguous_len`]).
pub struct ShardedStore {
    shards: Vec<GradStore>,
    /// `offsets[i]` = first global row of shard i; last entry = total rows.
    offsets: Vec<usize>,
    k: usize,
}

impl ShardedStore {
    pub fn open(dir: &Path) -> Result<Self> {
        if dir.join(SHARD_MANIFEST).exists() {
            let man = ShardManifest::load(dir)?;
            ensure!(
                man.codec == StoreCodec::F32,
                "store {} uses the {} codec; open it with QuantShardedStore \
                 (or serve it via the two-stage quantized scan)",
                dir.display(),
                man.codec.as_str()
            );
            let mut shards = Vec::with_capacity(man.n_shards());
            for (i, name) in man.shard_dirs.iter().enumerate() {
                let s = open_manifest_shard(&man, dir, i)?;
                ensure!(
                    s.k() == man.k,
                    "shard {name}: k={} disagrees with manifest k={}",
                    s.k(),
                    man.k
                );
                shards.push(s);
            }
            Ok(Self::from_shards(shards, man.k))
        } else {
            // Legacy v1 directory: a transparent 1-shard fabric.
            let s = GradStore::open(dir)?;
            let k = s.k();
            Ok(Self::from_shards(vec![s], k))
        }
    }

    /// Assemble a fabric from pre-opened shards (quarantined reloads open
    /// shards individually and skip the damaged ones).
    pub(crate) fn from_shards(shards: Vec<GradStore>, k: usize) -> Self {
        let mut offsets = Vec::with_capacity(shards.len() + 1);
        let mut acc = 0usize;
        offsets.push(0);
        for s in &shards {
            acc += s.rows();
            offsets.push(acc);
        }
        ShardedStore { shards, offsets, k }
    }

    pub fn k(&self) -> usize {
        self.k
    }

    pub fn rows(&self) -> usize {
        *self.offsets.last().unwrap()
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    pub fn shard(&self, i: usize) -> &GradStore {
        &self.shards[i]
    }

    /// First global row of shard i.
    pub fn shard_start(&self, i: usize) -> usize {
        self.offsets[i]
    }

    /// The single underlying [`GradStore`] when unsharded (lets callers
    /// keep using v1-only paths, e.g. the HLO-scoring sequential engine).
    pub fn as_single(&self) -> Option<&GradStore> {
        if self.shards.len() == 1 {
            Some(&self.shards[0])
        } else {
            None
        }
    }

    /// Global row -> (shard index, local row). Skips empty shards.
    pub fn locate(&self, row: usize) -> (usize, usize) {
        assert!(row < self.rows(), "row {row} out of range");
        let s = self.offsets.partition_point(|&o| o <= row) - 1;
        (s, row - self.offsets[s])
    }

    /// Rows available in a single contiguous `chunk` view starting at
    /// `start` (i.e. until the end of `start`'s shard).
    pub fn contiguous_len(&self, start: usize) -> usize {
        let (s, local) = self.locate(start);
        self.shards[s].rows() - local
    }

    /// Raw f32 view of global rows [start, start+len). Panics if the range
    /// crosses a shard boundary — scan loops should bound `len` by
    /// [`ShardedStore::contiguous_len`] or iterate shards directly.
    pub fn chunk(&self, start: usize, len: usize) -> &[f32] {
        if len == 0 {
            return &[];
        }
        let (s, local) = self.locate(start);
        assert!(
            local + len <= self.shards[s].rows(),
            "chunk [{start}, {start}+{len}) crosses a shard boundary"
        );
        self.shards[s].chunk(local, len)
    }

    /// One global row.
    pub fn row(&self, i: usize) -> &[f32] {
        self.chunk(i, 1)
    }

    /// Data id of global row i.
    pub fn id(&self, i: usize) -> u64 {
        let (s, local) = self.locate(i);
        self.shards[s].id(local)
    }

    /// Total stored bytes across shards (Table-1 "Storage" column).
    pub fn storage_bytes(&self) -> u64 {
        self.shards.iter().map(GradStore::storage_bytes).sum()
    }
}

/// Open shard `i` of a manifest, wrapping failure with the shard's path
/// plus the manifest-expected vs header-reported row counts — the error a
/// quarantine decision (and an operator) needs, instead of the bare
/// header complaint.
pub(crate) fn open_manifest_shard(
    man: &ShardManifest,
    dir: &Path,
    i: usize,
) -> Result<GradStore> {
    let name = &man.shard_dirs[i];
    let sdir = dir.join(name);
    GradStore::open(&sdir).map_err(|e| {
        let actual = read_v1_header(&sdir.join("grads.bin"))
            .map(|(_, rows)| rows.to_string())
            .unwrap_or_else(|_| "unreadable".to_string());
        e.context(format!(
            "shard {name} at {} failed validation: manifest expects {} rows, \
             header reports {actual}",
            sdir.display(),
            man.shard_rows[i]
        ))
    })
}

// ------------------------------------------------------------- operations

/// Split any store (v1 or already sharded) into `n_shards` contiguous
/// shards at `dst`, preserving global row order and data ids.
pub fn shard_store(src: &Path, dst: &Path, n_shards: usize) -> Result<ShardManifest> {
    ensure!(n_shards >= 1, "need at least one shard");
    let store = ShardedStore::open(src)?;
    let rows = store.rows();
    let k = store.k();
    let base = rows / n_shards;
    let rem = rows % n_shards;
    let mut writer = ShardedWriter::create(dst, k, n_shards)?;
    let mut at = 0usize;
    for shard in 0..n_shards {
        let want = base + usize::from(shard < rem);
        let mut copied = 0usize;
        while copied < want {
            let len = (want - copied).min(store.contiguous_len(at)).min(1024);
            let ids: Vec<u64> = (at..at + len).map(|g| store.id(g)).collect();
            writer.append_shard(shard, &ids, store.chunk(at, len))?;
            at += len;
            copied += len;
        }
    }
    writer.finalize()
}

/// Merge any store into a single v1 store at `dst` (global row order).
pub fn merge_store(src: &Path, dst: &Path) -> Result<u64> {
    let store = ShardedStore::open(src)?;
    let k = store.k();
    let mut w = GradStoreWriter::create(dst, k)?;
    let rows = store.rows();
    let mut at = 0usize;
    while at < rows {
        let len = store.contiguous_len(at).min(1024);
        let ids: Vec<u64> = (at..at + len).map(|g| store.id(g)).collect();
        w.append(&ids, store.chunk(at, len))?;
        at += len;
    }
    w.finalize()
}

// ------------------------------------------------------------------- stat

/// Per-shard on-disk byte sizes (the `store stat` breakdown): `data` is
/// `grads.bin` (f32 codec) or `codes.bin` (int8 codec), `scales` is
/// `scales.bin` (always 0 for f32 shards), `ids` is `ids.bin`.
#[derive(Clone, Copy, Debug, Default)]
pub struct ShardBytes {
    pub data: u64,
    pub scales: u64,
    pub ids: u64,
}

impl ShardBytes {
    pub fn total(&self) -> u64 {
        self.data + self.scales + self.ids
    }

    fn add(&mut self, other: &ShardBytes) {
        self.data += other.data;
        self.scales += other.scales;
        self.ids += other.ids;
    }
}

/// Summary of any store directory (the `store stat` CLI subcommand).
#[derive(Clone, Debug)]
pub struct StoreStat {
    pub codec: StoreCodec,
    /// Stage-0 index advertised by the manifest (`"ivf"`), if any.
    pub index: Option<String>,
    pub shards: usize,
    pub rows: usize,
    pub k: usize,
    pub storage_bytes: u64,
    pub shard_rows: Vec<usize>,
    /// Parallel to `shard_rows`: byte breakdown per shard, so bench
    /// artifacts and CI logs can correlate throughput with store size.
    pub shard_bytes: Vec<ShardBytes>,
}

/// Inspect a store directory (v1, sharded, or quantized) from its durable
/// headers, dispatching on the manifest's codec.
pub fn stat_store(dir: &Path) -> Result<StoreStat> {
    let (codec, index) = if dir.join(SHARD_MANIFEST).exists() {
        let man = ShardManifest::load(dir)?;
        (man.codec, man.index)
    } else if dir.join(super::quant::QUANT_CODES_FILE).exists() {
        (StoreCodec::Int8, None)
    } else {
        (StoreCodec::F32, None)
    };
    match codec {
        StoreCodec::F32 => {
            let store = ShardedStore::open(dir)?;
            Ok(StoreStat {
                codec,
                index,
                shards: store.n_shards(),
                rows: store.rows(),
                k: store.k(),
                storage_bytes: store.storage_bytes(),
                shard_rows: (0..store.n_shards()).map(|i| store.shard(i).rows()).collect(),
                shard_bytes: (0..store.n_shards())
                    .map(|i| {
                        let s = store.shard(i);
                        ShardBytes { data: s.grads_bytes(), scales: 0, ids: s.ids_bytes() }
                    })
                    .collect(),
            })
        }
        StoreCodec::Int8 => {
            let store = super::quant::QuantShardedStore::open(dir)?;
            Ok(StoreStat {
                codec,
                index,
                shards: store.n_shards(),
                rows: store.rows(),
                k: store.k(),
                storage_bytes: store.storage_bytes(),
                shard_rows: (0..store.n_shards()).map(|i| store.shard(i).rows()).collect(),
                shard_bytes: (0..store.n_shards())
                    .map(|i| {
                        let s = store.shard(i);
                        ShardBytes {
                            data: s.codes_bytes(),
                            scales: s.scales_bytes(),
                            ids: s.ids_bytes(),
                        }
                    })
                    .collect(),
            })
        }
    }
}

impl StoreStat {
    /// Summed per-component bytes across every shard.
    pub fn fabric_bytes(&self) -> ShardBytes {
        let mut total = ShardBytes::default();
        for b in &self.shard_bytes {
            total.add(b);
        }
        total
    }

    pub fn render(&self) -> String {
        use crate::util::memory::human_bytes;
        let data_label = match self.codec {
            StoreCodec::F32 => "grads",
            StoreCodec::Int8 => "codes",
        };
        let mut s = String::new();
        s.push_str(&format!("codec         {}\n", self.codec.as_str()));
        if let Some(ix) = &self.index {
            s.push_str(&format!("index         {ix}\n"));
        }
        s.push_str(&format!("shards        {}\n", self.shards));
        s.push_str(&format!("rows          {}\n", self.rows));
        s.push_str(&format!("k             {}\n", self.k));
        s.push_str(&format!(
            "storage_bytes {} ({})\n",
            self.storage_bytes,
            human_bytes(self.storage_bytes)
        ));
        for (i, (r, b)) in self.shard_rows.iter().zip(&self.shard_bytes).enumerate() {
            s.push_str(&format!(
                "  shard-{i:04}  {r} rows  {data_label} {}  scales {}  ids {}  ({})\n",
                b.data,
                b.scales,
                b.ids,
                human_bytes(b.total())
            ));
        }
        let total = self.fabric_bytes();
        s.push_str(&format!(
            "fabric bytes  {data_label} {}  scales {}  ids {}  ({})\n",
            total.data,
            total.scales,
            total.ids,
            human_bytes(total.total())
        ));
        s
    }
}

// The minimal JSON-subset parser the manifest uses lives in
// `crate::util::json` (shared with the trace/bench JSON validation in
// tests).
use crate::util::json;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn tmpdir(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("logra-shard-tests").join(name);
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn fill_sharded(
        dir: &Path,
        k: usize,
        n_shards: usize,
        batches: usize,
        seed: u64,
    ) -> (Vec<u64>, Vec<f32>) {
        // Returns (ids, rows) in GLOBAL order (shard concatenation).
        let mut w = ShardedWriter::create(dir, k, n_shards).unwrap();
        let mut rng = Pcg32::seeded(seed);
        let mut per_shard: Vec<(Vec<u64>, Vec<f32>)> =
            (0..n_shards).map(|_| (Vec::new(), Vec::new())).collect();
        let mut next_id = 0u64;
        for b in 0..batches {
            let shard = b % n_shards;
            let n = 1 + rng.below_usize(4);
            let ids: Vec<u64> = (0..n as u64).map(|i| next_id + i).collect();
            next_id += n as u64;
            let mut rows = vec![0.0f32; n * k];
            rng.fill_normal(&mut rows, 1.0);
            w.append_shard(shard, &ids, &rows).unwrap();
            per_shard[shard].0.extend_from_slice(&ids);
            per_shard[shard].1.extend_from_slice(&rows);
        }
        w.finalize().unwrap();
        let mut ids = Vec::new();
        let mut rows = Vec::new();
        for (i, r) in per_shard {
            ids.extend(i);
            rows.extend(r);
        }
        (ids, rows)
    }

    #[test]
    fn manifest_json_roundtrip() {
        for (codec, rescore_dir, index) in [
            (StoreCodec::F32, None, None),
            (StoreCodec::Int8, None, None),
            (StoreCodec::Int8, Some("/data/exact-store".to_string()), None),
            (
                StoreCodec::Int8,
                Some("/data/exact-store".to_string()),
                Some("ivf".to_string()),
            ),
        ] {
            let man = ShardManifest {
                k: 192,
                codec,
                generation: 7,
                rescore_dir,
                index,
                shard_dirs: vec!["shard-0000".into(), "shard-0001".into()],
                shard_rows: vec![128, 130],
            };
            let text = man.to_json();
            let back = ShardManifest::parse(&text).unwrap();
            assert_eq!(back, man);
            assert_eq!(back.offsets(), vec![0, 128, 258]);
            assert_eq!(back.total_rows(), 258);
        }
    }

    #[test]
    fn manifest_without_codec_parses_as_f32() {
        // The exact shape PR-1 manifests have on disk.
        let man = ShardManifest::parse(
            "{\"version\": 1, \"k\": 4, \"shards\": [{\"dir\": \"shard-0000\", \"rows\": 2}]}",
        )
        .unwrap();
        assert_eq!(man.codec, StoreCodec::F32);
        // And no rescore pointer (pre-PR5 manifests never carry one).
        assert_eq!(man.rescore_dir, None);
        // Nor an index advertisement (pre-PR8).
        assert_eq!(man.index, None);
        // Nor a generation (pre-live-growth): 0, never bumped.
        assert_eq!(man.generation, 0);
    }

    #[test]
    fn manifest_rejects_garbage() {
        assert!(ShardManifest::parse("").is_err());
        assert!(ShardManifest::parse("{\"version\": 2}").is_err());
        assert!(ShardManifest::parse("{\"version\": 1, \"k\": 4, \"shards\": []}").is_err());
        // Path traversal in shard dir names is rejected.
        assert!(ShardManifest::parse(
            "{\"version\": 1, \"k\": 4, \"shards\": [{\"dir\": \"../x\", \"rows\": 1}]}"
        )
        .is_err());
        // Unknown codecs are rejected, not silently defaulted.
        assert!(ShardManifest::parse(
            "{\"version\": 1, \"k\": 4, \"codec\": \"fp4\", \
             \"shards\": [{\"dir\": \"shard-0000\", \"rows\": 1}]}"
        )
        .is_err());
    }

    #[test]
    fn sharded_roundtrip_global_order() {
        let dir = tmpdir("roundtrip");
        let k = 5;
        let (ids, rows) = fill_sharded(&dir, k, 3, 10, 1);
        let s = ShardedStore::open(&dir).unwrap();
        assert_eq!(s.n_shards(), 3);
        assert_eq!(s.rows(), ids.len());
        assert_eq!(s.k(), k);
        assert!(s.as_single().is_none());
        for g in 0..s.rows() {
            assert_eq!(s.id(g), ids[g]);
            assert_eq!(s.row(g), &rows[g * k..(g + 1) * k]);
        }
        assert!(s.storage_bytes() > (rows.len() * 4) as u64);
    }

    #[test]
    fn legacy_v1_opens_as_single_shard() {
        let dir = tmpdir("legacy");
        let k = 4;
        let mut w = GradStoreWriter::create(&dir, k).unwrap();
        let rows = vec![1.0f32; 3 * k];
        w.append(&[7, 8, 9], &rows).unwrap();
        w.finalize().unwrap();
        let s = ShardedStore::open(&dir).unwrap();
        assert_eq!(s.n_shards(), 1);
        assert_eq!(s.rows(), 3);
        assert!(s.as_single().is_some());
        assert_eq!(s.chunk(0, 3), &rows[..]);
        assert_eq!(s.id(2), 9);
    }

    #[test]
    fn locate_skips_empty_shards() {
        let dir = tmpdir("empty-shard");
        let k = 2;
        let mut w = ShardedWriter::create(&dir, k, 3).unwrap();
        // Shard 1 stays empty.
        w.append_shard(0, &[0, 1], &[0.0; 4]).unwrap();
        w.append_shard(2, &[2], &[0.0; 2]).unwrap();
        w.finalize().unwrap();
        let s = ShardedStore::open(&dir).unwrap();
        assert_eq!(s.rows(), 3);
        assert_eq!(s.locate(0), (0, 0));
        assert_eq!(s.locate(1), (0, 1));
        assert_eq!(s.locate(2), (2, 0));
        assert_eq!(s.contiguous_len(1), 1);
        assert_eq!(s.id(2), 2);
    }

    #[test]
    #[should_panic(expected = "crosses a shard boundary")]
    fn chunk_across_boundary_panics() {
        let dir = tmpdir("boundary");
        let k = 2;
        let mut w = ShardedWriter::create(&dir, k, 2).unwrap();
        w.append_shard(0, &[0], &[0.0; 2]).unwrap();
        w.append_shard(1, &[1], &[0.0; 2]).unwrap();
        w.finalize().unwrap();
        let s = ShardedStore::open(&dir).unwrap();
        let _ = s.chunk(0, 2);
    }

    #[test]
    fn shard_then_merge_roundtrip() {
        let src = tmpdir("reshard-src");
        let k = 3;
        let mut w = GradStoreWriter::create(&src, k).unwrap();
        let mut rng = Pcg32::seeded(2);
        let n = 17;
        let mut rows = vec![0.0f32; n * k];
        rng.fill_normal(&mut rows, 1.0);
        let ids: Vec<u64> = (100..100 + n as u64).collect();
        w.append(&ids, &rows).unwrap();
        w.finalize().unwrap();

        let sharded = tmpdir("reshard-dst");
        let man = shard_store(&src, &sharded, 4).unwrap();
        assert_eq!(man.n_shards(), 4);
        assert_eq!(man.total_rows(), n as u64);
        // First publication of a freshly built store.
        assert_eq!(man.generation, 1);
        // Contiguous split: 5, 4, 4, 4.
        assert_eq!(man.shard_rows, vec![5, 4, 4, 4]);
        let s = ShardedStore::open(&sharded).unwrap();
        for g in 0..n {
            assert_eq!(s.id(g), ids[g]);
            assert_eq!(s.row(g), &rows[g * k..(g + 1) * k]);
        }

        let merged = tmpdir("reshard-merged");
        let total = merge_store(&sharded, &merged).unwrap();
        assert_eq!(total, n as u64);
        let m = GradStore::open(&merged).unwrap();
        assert_eq!(m.chunk(0, n), &rows[..]);
        for g in 0..n {
            assert_eq!(m.id(g), ids[g]);
        }
    }

    #[test]
    fn unfinalized_shard_durable_and_siblings_intact() {
        let dir = tmpdir("crash");
        let k = 3;
        let w = ShardedWriter::create(&dir, k, 3).unwrap();
        let mut writers = w.into_shard_writers();
        let mut rng = Pcg32::seeded(4);
        let mut per_shard: Vec<Vec<f32>> = vec![Vec::new(); 3];
        for (si, sw) in writers.iter_mut().enumerate() {
            let mut rows = vec![0.0f32; 4 * k];
            rng.fill_normal(&mut rows, 1.0);
            let ids: Vec<u64> = (si as u64 * 10..si as u64 * 10 + 4).collect();
            sw.append(&ids, &rows).unwrap();
            per_shard[si] = rows;
        }
        // "Crash": shard 1's writer is dropped without finalize.
        let w2 = writers.pop().unwrap(); // shard 2
        let w1 = writers.pop().unwrap(); // shard 1
        let w0 = writers.pop().unwrap(); // shard 0
        assert_eq!(w0.finalize().unwrap(), 4);
        drop(w1);
        assert_eq!(w2.finalize().unwrap(), 4);

        let s = ShardedStore::open(&dir).unwrap();
        // Unfinalized shard reports its last durable count (0)...
        assert_eq!(s.shard(1).rows(), 0);
        // ...without corrupting siblings.
        assert_eq!(s.rows(), 8);
        assert_eq!(s.shard(0).chunk(0, 4), &per_shard[0][..]);
        assert_eq!(s.shard(2).chunk(0, 4), &per_shard[2][..]);
        assert_eq!(s.id(4), 20); // global row 4 = shard 2 local 0

        // Reconcile syncs the advisory manifest counts to the headers and
        // republishes (generation 0 in the unfinalized manifest -> 1).
        let man = ShardManifest::reconcile(&dir).unwrap();
        assert_eq!(man.shard_rows, vec![4, 0, 4]);
        assert_eq!(man.generation, 1);
        assert_eq!(ShardManifest::load(&dir).unwrap(), man);
    }

    #[test]
    fn stat_reports_layout() {
        let dir = tmpdir("stat");
        let (ids, _) = fill_sharded(&dir, 6, 2, 6, 9);
        let st = stat_store(&dir).unwrap();
        assert_eq!(st.shards, 2);
        assert_eq!(st.rows, ids.len());
        assert_eq!(st.k, 6);
        assert!(st.storage_bytes > 0);
        assert_eq!(st.shard_rows.iter().sum::<usize>(), ids.len());
        // Per-shard byte breakdown is consistent with the fabric total.
        assert_eq!(st.shard_bytes.len(), st.shards);
        assert_eq!(st.fabric_bytes().total(), st.storage_bytes);
        for (r, b) in st.shard_rows.iter().zip(&st.shard_bytes) {
            assert_eq!(b.scales, 0, "f32 shards have no scales file");
            assert_eq!(b.ids, (*r * 8) as u64);
            assert_eq!(b.data, 32 + (*r * 6 * 4) as u64); // header + rows*k*f32
        }
        let text = st.render();
        assert!(text.contains("shards"));
        assert!(text.contains("storage_bytes"));
        assert!(text.contains("fabric bytes"));
        assert!(text.contains("grads"));
    }

    #[test]
    fn round_robin_append_balances() {
        let dir = tmpdir("rr");
        let k = 2;
        let mut w = ShardedWriter::create(&dir, k, 2).unwrap();
        for b in 0..4u64 {
            w.append(&[b], &[0.0; 2]).unwrap();
        }
        let man = w.finalize().unwrap();
        assert_eq!(man.shard_rows, vec![2, 2]);
    }
}
