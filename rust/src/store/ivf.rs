//! IVF (inverted-file) stage-0 index over a quantized store: per-shard
//! k-means centroids plus row-to-cluster assignment lists, so a query can
//! scan only the `nprobe` most promising clusters instead of every int8
//! row — the sublinear candidate generator in front of the two-stage
//! funnel (probe → int8 coarse scan → exact f32 rescore). "Sketching the
//! Readout of LLMs" (PAPERS.md) motivates exactly this retrieval structure
//! over a projected-gradient corpus.
//!
//! Layout (two files per shard, next to `codes.bin`):
//!
//! ```text
//! <shard>/centroids.bin  header(32B) + clusters * k * f32 (row-major)
//! <shard>/lists.bin      header(32B) + clusters * u64 list lengths
//!                        + rows * u32 local row indices (per-cluster
//!                        lists concatenated, each sorted ascending)
//! ```
//!
//! Headers follow the LOGRA convention: `centroids.bin` is magic
//! "LOGRAIVC", u32 version=1, u32 k, u64 clusters, 8B pad; `lists.bin` is
//! magic "LOGRAIVL", u32 version=1, u32 clusters, u64 rows, 8B pad. The
//! manifest advertises a built index via `"index": "ivf"` — manifests
//! without the field parse unchanged, so pre-index stores keep opening.
//!
//! Crash/staleness consistency: the index is DERIVED data. [`IvfIndex::open`]
//! validates each shard's pair of files (magic, version, k, cluster/row
//! agreement with the live quantized shard, list coverage of every row
//! exactly once) and **falls back per shard** — a truncated `lists.bin`
//! or a shard re-written after indexing degrades that one shard to a full
//! coarse scan instead of corrupting results or failing the open. Within
//! a shard, `centroids.bin` is written (and synced) before `lists.bin`,
//! so a crash mid-build never leaves lists without their centroids.
//!
//! Determinism: k-means is seeded ([`crate::util::rng::Pcg32`], one
//! stream per shard), initialized by distinct-row sampling, iterated a
//! fixed number of rounds with first-wins tie-breaking — `build_index`
//! over the same store and seed reproduces the same bytes.

use std::fs::File;
use std::io::Write;
use std::path::Path;

use anyhow::{anyhow, ensure, Context, Result};

use super::quant::{QuantShardedStore, QuantStore};
use super::shards::{ShardManifest, StoreCodec, SHARD_MANIFEST};

/// Centroid file name inside a shard directory.
pub const IVF_CENTROIDS_FILE: &str = "centroids.bin";
/// Assignment-list file name inside a shard directory.
pub const IVF_LISTS_FILE: &str = "lists.bin";
/// Manifest `"index"` value advertising this index type.
pub const IVF_INDEX_NAME: &str = "ivf";

const CENTROIDS_MAGIC: &[u8; 8] = b"LOGRAIVC";
const LISTS_MAGIC: &[u8; 8] = b"LOGRAIVL";
const VERSION: u32 = 1;
const HEADER_LEN: usize = 32;

/// Fixed k-means rounds: enough to settle the well-separated case this
/// index targets, bounded so build time stays linear and deterministic.
const KMEANS_ITERS: usize = 10;

fn centroids_header(k: u32, clusters: u64) -> [u8; HEADER_LEN] {
    let mut h = [0u8; HEADER_LEN];
    h[..8].copy_from_slice(CENTROIDS_MAGIC);
    h[8..12].copy_from_slice(&VERSION.to_le_bytes());
    h[12..16].copy_from_slice(&k.to_le_bytes());
    h[16..24].copy_from_slice(&clusters.to_le_bytes());
    h
}

fn lists_header(clusters: u32, rows: u64) -> [u8; HEADER_LEN] {
    let mut h = [0u8; HEADER_LEN];
    h[..8].copy_from_slice(LISTS_MAGIC);
    h[8..12].copy_from_slice(&VERSION.to_le_bytes());
    h[12..16].copy_from_slice(&clusters.to_le_bytes());
    h[16..24].copy_from_slice(&rows.to_le_bytes());
    h
}

// ------------------------------------------------------------------ build

/// Build summary returned by [`build_index`] (the `store index` CLI
/// report): per-shard cluster and row counts.
#[derive(Clone, Debug)]
pub struct IvfBuildReport {
    pub shards: usize,
    /// Clusters actually built per shard (≤ requested: capped at rows).
    pub clusters: Vec<usize>,
    pub rows: Vec<usize>,
}

/// Run seeded k-means over each shard of the quantized store at `dir`,
/// persist per-shard `centroids.bin` + `lists.bin`, and advertise the
/// index in the manifest (`"index": "ivf"`). Deterministic in
/// (store bytes, `clusters`, `seed`). The cluster count is capped per
/// shard at the shard's row count; empty shards get empty index files.
pub fn build_index(dir: &Path, clusters: usize, seed: u64) -> Result<IvfBuildReport> {
    ensure!(clusters >= 1, "index needs at least one cluster");
    ensure!(
        dir.join(SHARD_MANIFEST).exists(),
        "store {} has no {SHARD_MANIFEST} manifest; \
         `logra store quantize` writes one — the index must be advertised there",
        dir.display()
    );
    let man = ShardManifest::load(dir)?;
    ensure!(
        man.codec == StoreCodec::Int8,
        "store {} uses the {} codec; the IVF index clusters int8 codes — \
         run `logra store quantize` first",
        dir.display(),
        man.codec.as_str()
    );
    let store = QuantShardedStore::open(dir)?;
    let mut report = IvfBuildReport {
        shards: store.n_shards(),
        clusters: Vec::with_capacity(store.n_shards()),
        rows: Vec::with_capacity(store.n_shards()),
    };
    for si in 0..store.n_shards() {
        let shard = store.shard(si);
        let shard_dir = dir.join(&man.shard_dirs[si]);
        let built = build_shard_index(shard, &shard_dir, clusters, seed, si as u64)
            .with_context(|| format!("index shard {si} of {}", dir.display()))?;
        report.clusters.push(built);
        report.rows.push(shard.rows());
    }
    // Fault point: silent sidecar damage after a successful build — the
    // per-shard validation in `IvfIndex::open` must degrade this to a
    // full-scan fallback, never a wrong answer.
    if !man.shard_dirs.is_empty() {
        super::fault::maybe_truncate(
            "ivf_corrupt",
            &dir.join(&man.shard_dirs[0]).join(IVF_LISTS_FILE),
        );
    }
    let mut man = man;
    man.index = Some(IVF_INDEX_NAME.to_string());
    // Advertising the index is a content change readers may be polling
    // for: republish as the next generation.
    man.generation += 1;
    man.save(dir)?;
    Ok(report)
}

/// What an incremental index pass did.
#[derive(Clone, Copy, Debug, Default)]
pub struct IvfIncrementalReport {
    /// Shards (re)indexed this pass.
    pub indexed: usize,
    /// Shards whose sidecar pair already validated against the live shard.
    pub skipped: usize,
}

/// Incremental [`build_index`]: (re)index only the shards whose
/// `centroids.bin`/`lists.bin` pair is missing, damaged, or stale against
/// the live shard (the same per-shard validation [`IvfIndex::open`] uses
/// to decide fallback) — the mirror of `quantize --incremental` for the
/// IVF sidecars, closing the staleness window `logra store append` opens.
/// Seed streams stay per-shard (`si`), so a shard indexed incrementally
/// is byte-identical to the same shard indexed by a full [`build_index`]
/// pass with the same `(clusters, seed)`. The generation advances only
/// when at least one shard was actually (re)built.
pub fn build_index_incremental(
    dir: &Path,
    clusters: usize,
    seed: u64,
) -> Result<IvfIncrementalReport> {
    ensure!(clusters >= 1, "index needs at least one cluster");
    ensure!(
        dir.join(SHARD_MANIFEST).exists(),
        "store {} has no {SHARD_MANIFEST} manifest; \
         `logra store quantize` writes one — the index must be advertised there",
        dir.display()
    );
    let man = ShardManifest::load(dir)?;
    ensure!(
        man.codec == StoreCodec::Int8,
        "store {} uses the {} codec; the IVF index clusters int8 codes — \
         run `logra store quantize` first",
        dir.display(),
        man.codec.as_str()
    );
    let store = QuantShardedStore::open(dir)?;
    let mut report = IvfIncrementalReport::default();
    for si in 0..store.n_shards() {
        let shard = store.shard(si);
        let shard_dir = dir.join(&man.shard_dirs[si]);
        if load_shard_index(&shard_dir, shard).is_ok() {
            report.skipped += 1;
            continue;
        }
        build_shard_index(shard, &shard_dir, clusters, seed, si as u64)
            .with_context(|| format!("index shard {si} of {}", dir.display()))?;
        report.indexed += 1;
    }
    let advertised = man.index.as_deref() == Some(IVF_INDEX_NAME);
    if report.indexed > 0 || !advertised {
        let mut man = man;
        man.index = Some(IVF_INDEX_NAME.to_string());
        man.generation += 1;
        man.save(dir)?;
    }
    Ok(report)
}

/// K-means one shard and write its two index files. Returns the cluster
/// count actually built. `centroids.bin` is written and synced before
/// `lists.bin` so a crash between the two leaves an openable (rejected,
/// fallback) state rather than lists pointing at missing centroids.
fn build_shard_index(
    shard: &QuantStore,
    shard_dir: &Path,
    clusters: usize,
    seed: u64,
    stream: u64,
) -> Result<usize> {
    let k = shard.k();
    let rows = shard.rows();
    let c = clusters.min(rows);
    // Dequantize once: k-means runs in f32 over the reconstructed rows
    // (the same values stage 1 scores against, up to quantization).
    let mut data = vec![0.0f32; rows * k];
    for r in 0..rows {
        super::quant::dequantize_row(
            shard.codes_chunk(r, 1),
            shard.scales_chunk(r, 1),
            &mut data[r * k..(r + 1) * k],
        );
    }
    let (centroids, assign) = kmeans(&data, rows, k, c, seed, stream);

    // Per-cluster lists, each sorted ascending (rows are visited in order).
    let mut lists: Vec<Vec<u32>> = vec![Vec::new(); c];
    for (r, &a) in assign.iter().enumerate() {
        lists[a as usize].push(r as u32);
    }

    let cpath = shard_dir.join(IVF_CENTROIDS_FILE);
    let mut cf = File::create(&cpath).with_context(|| format!("create {}", cpath.display()))?;
    cf.write_all(&centroids_header(k as u32, c as u64))?;
    cf.write_all(f32_bytes(&centroids))?;
    cf.sync_all()?;

    let lpath = shard_dir.join(IVF_LISTS_FILE);
    let mut lf = File::create(&lpath).with_context(|| format!("create {}", lpath.display()))?;
    lf.write_all(&lists_header(c as u32, rows as u64))?;
    for l in &lists {
        lf.write_all(&(l.len() as u64).to_le_bytes())?;
    }
    for l in &lists {
        lf.write_all(u32_bytes(l))?;
    }
    lf.sync_all()?;
    Ok(c)
}

fn f32_bytes(v: &[f32]) -> &[u8] {
    // f32 bytes come from (and are read back on) this machine.
    unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4) }
}

fn u32_bytes(v: &[u32]) -> &[u8] {
    unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4) }
}

/// Seeded Lloyd k-means over `n` row-major rows of width `k`: returns
/// (centroids [c, k], per-row assignment [n]). Sequential and
/// deterministic: distinct-row init via [`Pcg32::sample_indices`], fixed
/// iteration count, first-wins tie-breaking, empty clusters reseeded to a
/// seeded random row.
fn kmeans(data: &[f32], n: usize, k: usize, c: usize, seed: u64, stream: u64) -> (Vec<f32>, Vec<u32>) {
    use crate::util::rng::Pcg32;
    if c == 0 {
        return (Vec::new(), Vec::new());
    }
    let mut rng = Pcg32::new(seed, stream);
    let mut centroids = vec![0.0f32; c * k];
    for (ci, &r) in rng.sample_indices(n, c).iter().enumerate() {
        centroids[ci * k..(ci + 1) * k].copy_from_slice(&data[r * k..(r + 1) * k]);
    }
    let mut assign = vec![0u32; n];
    let mut counts = vec![0usize; c];
    for _ in 0..KMEANS_ITERS {
        // Assignment: nearest centroid by squared L2, first wins on ties.
        for (r, a) in assign.iter_mut().enumerate() {
            let x = &data[r * k..(r + 1) * k];
            let mut best = 0usize;
            let mut best_d = f32::INFINITY;
            for (ci, cen) in centroids.chunks_exact(k).enumerate() {
                let mut d = 0.0f32;
                for (xv, cv) in x.iter().zip(cen) {
                    let t = xv - cv;
                    d += t * t;
                }
                if d < best_d {
                    best_d = d;
                    best = ci;
                }
            }
            *a = best as u32;
        }
        // Update: means per cluster; empty clusters reseed to a random row.
        centroids.iter_mut().for_each(|v| *v = 0.0);
        counts.iter_mut().for_each(|v| *v = 0);
        for (r, &a) in assign.iter().enumerate() {
            let cen = &mut centroids[a as usize * k..(a as usize + 1) * k];
            for (cv, xv) in cen.iter_mut().zip(&data[r * k..(r + 1) * k]) {
                *cv += xv;
            }
            counts[a as usize] += 1;
        }
        for (ci, &cnt) in counts.iter().enumerate() {
            let cen = &mut centroids[ci * k..(ci + 1) * k];
            if cnt > 0 {
                let inv = 1.0 / cnt as f32;
                cen.iter_mut().for_each(|v| *v *= inv);
            } else {
                let r = rng.below_usize(n);
                cen.copy_from_slice(&data[r * k..(r + 1) * k]);
            }
        }
    }
    // Final assignment against the settled centroids (the lists must match
    // the centroids that were just written).
    for (r, a) in assign.iter_mut().enumerate() {
        let x = &data[r * k..(r + 1) * k];
        let mut best = 0usize;
        let mut best_d = f32::INFINITY;
        for (ci, cen) in centroids.chunks_exact(k).enumerate() {
            let mut d = 0.0f32;
            for (xv, cv) in x.iter().zip(cen) {
                let t = xv - cv;
                d += t * t;
            }
            if d < best_d {
                best_d = d;
                best = ci;
            }
        }
        *a = best as u32;
    }
    (centroids, assign)
}

// ------------------------------------------------------------------- open

/// One shard's loaded index: centroids and per-cluster row lists.
#[derive(Clone, Debug)]
pub struct IvfShard {
    k: usize,
    /// Row-major [clusters, k] cluster centers.
    centroids: Vec<f32>,
    /// Per-cluster local row indices, each sorted ascending; disjoint and
    /// jointly covering every shard row exactly once (validated at open).
    lists: Vec<Vec<u32>>,
}

impl IvfShard {
    pub fn clusters(&self) -> usize {
        self.lists.len()
    }

    /// Local rows assigned to cluster `ci`, sorted ascending.
    pub fn list(&self, ci: usize) -> &[u32] {
        &self.lists[ci]
    }

    pub fn centroid(&self, ci: usize) -> &[f32] {
        &self.centroids[ci * self.k..(ci + 1) * self.k]
    }

    /// Stage-0 probe: rank clusters by inner product against each of the
    /// `nt` (already preconditioned) test rows, union each row's top
    /// `nprobe` clusters, and return the union's local rows, sorted
    /// ascending. With `nprobe >= clusters()` this is every row of the
    /// shard — the bit-identity anchor for the full-probe equivalence.
    pub fn probe(&self, pre: &[f32], nt: usize, nprobe: usize) -> Vec<u32> {
        let c = self.clusters();
        if c == 0 {
            return Vec::new();
        }
        let nprobe = nprobe.min(c);
        let mut selected = vec![false; c];
        let mut scored: Vec<(f64, usize)> = Vec::with_capacity(c);
        for t in 0..nt {
            let x = &pre[t * self.k..(t + 1) * self.k];
            scored.clear();
            for ci in 0..c {
                let mut s = 0.0f32;
                for (xv, cv) in x.iter().zip(self.centroid(ci)) {
                    s += xv * cv;
                }
                scored.push((s as f64, ci));
            }
            // Descending score, ties to the smaller cluster index — the
            // same total-order discipline as TopK, so the probed set is a
            // pure function of the scores.
            scored.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap().then(a.1.cmp(&b.1)));
            for &(_, ci) in scored.iter().take(nprobe) {
                selected[ci] = true;
            }
        }
        let mut rows: Vec<u32> = Vec::new();
        for (ci, sel) in selected.iter().enumerate() {
            if *sel {
                rows.extend_from_slice(&self.lists[ci]);
            }
        }
        // Lists are disjoint; sorting restores global ascending order so
        // the scan can coalesce contiguous runs.
        rows.sort_unstable();
        rows
    }
}

/// Loaded IVF index over a quantized fabric: one optional entry per
/// shard. `None` means that shard's index files were missing, truncated,
/// or stale against the live shard — the engine falls back to a full
/// coarse scan there (correctness is never a function of index health).
pub struct IvfIndex {
    shards: Vec<Option<IvfShard>>,
}

impl IvfIndex {
    /// Load the index for every shard of `store` from `dir`, tolerating
    /// per-shard damage (see type docs). Errors only on structural
    /// impossibilities (manifest unreadable), not on index-file damage.
    pub fn open(dir: &Path, store: &QuantShardedStore) -> Result<Self> {
        let man = ShardManifest::load(dir)?;
        ensure!(
            man.n_shards() == store.n_shards(),
            "manifest shard count {} disagrees with store {}",
            man.n_shards(),
            store.n_shards()
        );
        let mut shards = Vec::with_capacity(store.n_shards());
        for si in 0..store.n_shards() {
            let shard_dir = dir.join(&man.shard_dirs[si]);
            shards.push(load_shard_index(&shard_dir, store.shard(si)).ok());
        }
        Ok(IvfIndex { shards })
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// The loaded index of shard `si`, or `None` if that shard fell back.
    pub fn shard(&self, si: usize) -> Option<&IvfShard> {
        self.shards[si].as_ref()
    }

    /// Shards that fell back to a full coarse scan (damaged/missing/stale
    /// index files) — surfaced so operators can see degraded probes.
    pub fn fallback_shards(&self) -> usize {
        self.shards.iter().filter(|s| s.is_none()).count()
    }

    /// Largest per-shard cluster count (0 when every shard fell back) —
    /// `nprobe >= max_clusters()` probes every cluster everywhere.
    pub fn max_clusters(&self) -> usize {
        self.shards
            .iter()
            .filter_map(|s| s.as_ref().map(IvfShard::clusters))
            .max()
            .unwrap_or(0)
    }
}

/// Validate and load one shard's index pair. Every rejection path is an
/// `Err` — the caller degrades it to a per-shard fallback.
fn load_shard_index(shard_dir: &Path, shard: &QuantStore) -> Result<IvfShard> {
    let k = shard.k();
    let rows = shard.rows();

    let cbytes = std::fs::read(shard_dir.join(IVF_CENTROIDS_FILE))?;
    ensure!(cbytes.len() >= HEADER_LEN, "centroids.bin truncated header");
    ensure!(&cbytes[..8] == CENTROIDS_MAGIC, "bad centroids.bin magic");
    let cver = u32::from_le_bytes(cbytes[8..12].try_into().unwrap());
    ensure!(cver == VERSION, "centroids.bin version {cver} unsupported");
    let ck = u32::from_le_bytes(cbytes[12..16].try_into().unwrap()) as usize;
    ensure!(ck == k, "centroids.bin k={ck} != shard k={k}");
    let c = u64::from_le_bytes(cbytes[16..24].try_into().unwrap()) as usize;
    ensure!(c <= rows, "centroids.bin clusters {c} > shard rows {rows}");
    ensure!(c >= 1 || rows == 0, "centroids.bin has zero clusters");
    let need = HEADER_LEN + c * k * 4;
    ensure!(cbytes.len() >= need, "centroids.bin truncated payload");
    let mut centroids = vec![0.0f32; c * k];
    for (i, v) in centroids.iter_mut().enumerate() {
        let at = HEADER_LEN + i * 4;
        *v = f32::from_le_bytes(cbytes[at..at + 4].try_into().unwrap());
    }

    let lbytes = std::fs::read(shard_dir.join(IVF_LISTS_FILE))?;
    ensure!(lbytes.len() >= HEADER_LEN, "lists.bin truncated header");
    ensure!(&lbytes[..8] == LISTS_MAGIC, "bad lists.bin magic");
    let lver = u32::from_le_bytes(lbytes[8..12].try_into().unwrap());
    ensure!(lver == VERSION, "lists.bin version {lver} unsupported");
    let lc = u32::from_le_bytes(lbytes[12..16].try_into().unwrap()) as usize;
    ensure!(lc == c, "lists.bin clusters {lc} != centroids.bin {c}");
    let lrows = u64::from_le_bytes(lbytes[16..24].try_into().unwrap()) as usize;
    // Staleness fence: a shard re-written (or re-finalized) after indexing
    // invalidates the assignment lists.
    ensure!(lrows == rows, "lists.bin rows {lrows} != live shard rows {rows} (stale index)");
    let need = HEADER_LEN + c * 8 + rows * 4;
    ensure!(lbytes.len() >= need, "lists.bin truncated payload");
    let mut lens = Vec::with_capacity(c);
    for ci in 0..c {
        let at = HEADER_LEN + ci * 8;
        lens.push(u64::from_le_bytes(lbytes[at..at + 8].try_into().unwrap()) as usize);
    }
    ensure!(
        lens.iter().sum::<usize>() == rows,
        "lists.bin lengths do not cover the shard"
    );
    let mut lists = Vec::with_capacity(c);
    let mut seen = vec![false; rows];
    let mut at = HEADER_LEN + c * 8;
    for (ci, &len) in lens.iter().enumerate() {
        let mut list = Vec::with_capacity(len);
        let mut prev: Option<u32> = None;
        for _ in 0..len {
            let r = u32::from_le_bytes(lbytes[at..at + 4].try_into().unwrap());
            at += 4;
            ensure!((r as usize) < rows, "lists.bin row {r} out of range in cluster {ci}");
            ensure!(prev.map_or(true, |p| p < r), "lists.bin cluster {ci} not sorted");
            ensure!(!seen[r as usize], "lists.bin row {r} assigned twice");
            seen[r as usize] = true;
            prev = Some(r);
            list.push(r);
        }
        lists.push(list);
    }
    Ok(IvfShard { k, centroids, lists })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::store::quant::quantize_store;
    use crate::store::GradStoreWriter;
    use crate::util::rng::Pcg32;
    use std::path::PathBuf;

    fn tmpdir(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("logra-ivf-tests").join(name);
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    /// f32 source -> sharded -> quantized store; returns the quantized dir.
    fn quantized_fixture(name: &str, n: usize, k: usize, shards: usize) -> PathBuf {
        let src = tmpdir(&format!("{name}-src"));
        let mut rng = Pcg32::seeded(0x1F5);
        let mut rows = vec![0.0f32; n * k];
        rng.fill_normal(&mut rows, 1.0);
        let ids: Vec<u64> = (0..n as u64).collect();
        let mut w = GradStoreWriter::create(&src, k).unwrap();
        w.append(&ids, &rows).unwrap();
        w.finalize().unwrap();
        let sharded = tmpdir(&format!("{name}-sharded"));
        crate::store::shard_store(&src, &sharded, shards).unwrap();
        let dst = tmpdir(&format!("{name}-q8"));
        quantize_store(&sharded, &dst).unwrap();
        dst
    }

    #[test]
    fn build_open_roundtrip_covers_every_row() {
        let dir = quantized_fixture("roundtrip", 120, 12, 3);
        let report = build_index(&dir, 5, 42).unwrap();
        assert_eq!(report.shards, 3);
        assert_eq!(report.clusters, vec![5, 5, 5]);
        assert_eq!(ShardManifest::load(&dir).unwrap().index.as_deref(), Some("ivf"));

        let store = QuantShardedStore::open(&dir).unwrap();
        let index = IvfIndex::open(&dir, &store).unwrap();
        assert_eq!(index.fallback_shards(), 0);
        assert_eq!(index.max_clusters(), 5);
        for si in 0..3 {
            let sh = index.shard(si).expect("valid shard index");
            let total: usize = (0..sh.clusters()).map(|c| sh.list(c).len()).sum();
            assert_eq!(total, store.shard(si).rows());
            // Full probe touches every row exactly once, sorted.
            let pre = vec![0.5f32; 12];
            let probed = sh.probe(&pre, 1, sh.clusters());
            assert_eq!(probed.len(), store.shard(si).rows());
            assert!(probed.windows(2).all(|w| w[0] < w[1]));
        }
    }

    #[test]
    fn build_is_deterministic_per_seed() {
        let dir_a = quantized_fixture("det-a", 80, 8, 2);
        let dir_b = quantized_fixture("det-b", 80, 8, 2);
        build_index(&dir_a, 4, 7).unwrap();
        build_index(&dir_b, 4, 7).unwrap();
        for si in 0..2 {
            let sd = format!("shard-{si:04}");
            for f in [IVF_CENTROIDS_FILE, IVF_LISTS_FILE] {
                let a = std::fs::read(dir_a.join(&sd).join(f)).unwrap();
                let b = std::fs::read(dir_b.join(&sd).join(f)).unwrap();
                assert_eq!(a, b, "{sd}/{f} differs across identical builds");
            }
        }
    }

    #[test]
    fn truncated_lists_fall_back_per_shard() {
        let dir = quantized_fixture("truncate", 90, 6, 3);
        build_index(&dir, 4, 1).unwrap();
        // Crash simulation: shard 1's lists.bin is cut mid-payload.
        let lpath = dir.join("shard-0001").join(IVF_LISTS_FILE);
        let bytes = std::fs::read(&lpath).unwrap();
        std::fs::write(&lpath, &bytes[..bytes.len() / 2]).unwrap();

        let store = QuantShardedStore::open(&dir).unwrap();
        let index = IvfIndex::open(&dir, &store).unwrap();
        assert_eq!(index.fallback_shards(), 1);
        assert!(index.shard(0).is_some());
        assert!(index.shard(1).is_none(), "damaged shard must fall back");
        assert!(index.shard(2).is_some());
    }

    #[test]
    fn missing_files_and_bad_magic_fall_back() {
        let dir = quantized_fixture("missing", 40, 4, 2);
        // No index built at all: every shard falls back, open still works.
        let store = QuantShardedStore::open(&dir).unwrap();
        let index = IvfIndex::open(&dir, &store).unwrap();
        assert_eq!(index.fallback_shards(), 2);
        assert_eq!(index.max_clusters(), 0);

        build_index(&dir, 3, 2).unwrap();
        std::fs::write(dir.join("shard-0000").join(IVF_CENTROIDS_FILE), b"JUNKJUNK").unwrap();
        let index = IvfIndex::open(&dir, &store).unwrap();
        assert_eq!(index.fallback_shards(), 1);
    }

    #[test]
    fn clusters_capped_at_shard_rows() {
        let dir = quantized_fixture("cap", 10, 4, 2);
        let report = build_index(&dir, 64, 3).unwrap();
        assert_eq!(report.clusters, vec![5, 5]);
        let store = QuantShardedStore::open(&dir).unwrap();
        let index = IvfIndex::open(&dir, &store).unwrap();
        assert_eq!(index.fallback_shards(), 0);
        assert_eq!(index.max_clusters(), 5);
    }

    #[test]
    fn incremental_indexes_only_stale_shards() {
        let dir = quantized_fixture("incr", 90, 6, 3);
        build_index(&dir, 4, 9).unwrap();
        let gen_full = ShardManifest::load(&dir).unwrap().generation;

        // Nothing stale: pure skip, no generation churn.
        let report = build_index_incremental(&dir, 4, 9).unwrap();
        assert_eq!(report.indexed, 0);
        assert_eq!(report.skipped, 3);
        assert_eq!(ShardManifest::load(&dir).unwrap().generation, gen_full);

        // Damage one shard's sidecar: only that shard is rebuilt, and the
        // rebuilt bytes match the original full build (same seed stream).
        let lpath = dir.join("shard-0001").join(IVF_LISTS_FILE);
        let original = std::fs::read(&lpath).unwrap();
        std::fs::write(&lpath, &original[..original.len() / 2]).unwrap();
        let report = build_index_incremental(&dir, 4, 9).unwrap();
        assert_eq!(report.indexed, 1);
        assert_eq!(report.skipped, 2);
        assert_eq!(std::fs::read(&lpath).unwrap(), original);
        assert_eq!(ShardManifest::load(&dir).unwrap().generation, gen_full + 1);

        let store = QuantShardedStore::open(&dir).unwrap();
        let index = IvfIndex::open(&dir, &store).unwrap();
        assert_eq!(index.fallback_shards(), 0);
    }

    #[test]
    fn incremental_on_unindexed_store_builds_everything() {
        let dir = quantized_fixture("incr-fresh", 40, 4, 2);
        let report = build_index_incremental(&dir, 3, 5).unwrap();
        assert_eq!(report.indexed, 2);
        assert_eq!(report.skipped, 0);
        assert_eq!(ShardManifest::load(&dir).unwrap().index.as_deref(), Some("ivf"));
    }

    #[test]
    fn rejects_f32_and_unmanifested_stores() {
        let src = tmpdir("reject-f32");
        let mut w = GradStoreWriter::create(&src, 4).unwrap();
        w.append(&[0], &[1.0; 4]).unwrap();
        w.finalize().unwrap();
        // Bare v1 dir: no manifest to advertise the index in.
        assert!(build_index(&src, 2, 0).is_err());
        let sharded = tmpdir("reject-f32-sharded");
        crate::store::shard_store(&src, &sharded, 1).unwrap();
        // Manifested but f32: the index clusters int8 codes.
        let err = build_index(&sharded, 2, 0).unwrap_err().to_string();
        assert!(err.contains("codec"), "unexpected error: {err}");
    }
}
