//! Test-only fault injection for the store I/O path.
//!
//! The live-growing store promises that a reader always sees either the
//! previous generation intact or the new one completely — never a blend.
//! That promise is only worth anything if it survives torn writes, crashes
//! mid-finalize, and silent sidecar corruption, so the durability-critical
//! code paths carry named *fault points* that this module can arm:
//!
//! | point               | where it fires                       | effect |
//! |---------------------|--------------------------------------|--------|
//! | `manifest_tear`     | [`ShardManifest::save`]              | temp file written + synced, rename skipped, `Err` returned (crash before publish) |
//! | `publish_delay`     | [`ShardManifest::save`]              | sleep `arg` ms between fsync and rename (widens the publish race window) |
//! | `finalize_truncate` | [`GradStoreWriter::finalize`]        | header patched with the full row count but the data payload truncated, `Err` returned (torn write) |
//! | `quant_corrupt`     | [`QuantWriter::finalize`]            | `codes.bin` silently truncated after an otherwise successful finalize (bit rot) |
//! | `ivf_corrupt`       | [`build_index`]                      | a shard's `lists.bin` silently truncated after the build (stale/damaged sidecar) |
//!
//! Faults are armed either from the `LOGRA_FAULT` environment variable
//! (comma-separated `point` or `point=arg` entries, read once at first
//! use — the right interface for CLI-level tests that fault a whole
//! `logra store append` process) or programmatically via [`arm`] /
//! [`disarm`] (the right interface for in-process `cargo test`, where
//! mutating the environment from multiple test threads is unsound).
//!
//! The armed set is process-global, and `cargo test` runs tests
//! concurrently in one process — so for every path-bearing point
//! (`manifest_tear`, `finalize_truncate`, `quant_corrupt`,
//! `ivf_corrupt`), the optional `=arg` is a **path substring filter**:
//! `finalize_truncate=my-test-dir` only fires on files whose path
//! contains `my-test-dir`. Tests arm faults filtered to their own temp
//! directories and never perturb a concurrently running sibling. A bare
//! point (no `=arg`) fires everywhere, which is what `LOGRA_FAULT` wants
//! in a single-operation CLI process. `publish_delay`'s arg is the delay
//! in milliseconds instead.
//!
//! When nothing is armed every hook is a single mutex-guarded `Option`
//! check on a cold path (manifest publication, shard finalize) — the hot
//! scan path never consults this module.
//!
//! [`ShardManifest::save`]: super::ShardManifest::save
//! [`GradStoreWriter::finalize`]: super::GradStoreWriter::finalize
//! [`QuantWriter::finalize`]: super::QuantWriter::finalize
//! [`build_index`]: super::build_index

use std::sync::Mutex;

use anyhow::{bail, Result};

/// Armed fault entries as `(point, optional arg)` pairs. `None` means the
/// `LOGRA_FAULT` environment variable has not been consulted yet.
static ARMED: Mutex<Option<Vec<(String, Option<String>)>>> = Mutex::new(None);

/// Serializes fault-driven tests: [`arm`] and [`disarm`] replace the whole
/// armed set, so two tests interleaving them would cancel each other's
/// faults. Hold the returned guard for the entire armed window.
static EXCLUSIVE: Mutex<()> = Mutex::new(());

pub fn exclusive() -> std::sync::MutexGuard<'static, ()> {
    EXCLUSIVE.lock().unwrap_or_else(|e| e.into_inner())
}

fn parse_spec(spec: &str) -> Vec<(String, Option<String>)> {
    spec.split(',')
        .map(str::trim)
        .filter(|s| !s.is_empty())
        .map(|entry| match entry.split_once('=') {
            Some((point, arg)) => (point.to_string(), Some(arg.to_string())),
            None => (entry.to_string(), None),
        })
        .collect()
}

/// Arm the given fault spec for this process, replacing whatever was armed
/// before (including anything inherited from `LOGRA_FAULT`).
pub fn arm(spec: &str) {
    let mut armed = ARMED.lock().unwrap_or_else(|e| e.into_inner());
    *armed = Some(parse_spec(spec));
}

/// Disarm every fault. The environment variable is *not* re-read: after
/// `disarm()` the process runs fault-free until the next [`arm`].
pub fn disarm() {
    let mut armed = ARMED.lock().unwrap_or_else(|e| e.into_inner());
    *armed = Some(Vec::new());
}

/// Look up a fault point. Returns `Some(arg)` when armed (`arg` is the
/// `=value` part, if any). First call initializes from `LOGRA_FAULT`.
pub fn armed(point: &str) -> Option<Option<String>> {
    let mut guard = ARMED.lock().unwrap_or_else(|e| e.into_inner());
    let entries = guard.get_or_insert_with(|| {
        std::env::var("LOGRA_FAULT")
            .map(|s| parse_spec(&s))
            .unwrap_or_default()
    });
    entries
        .iter()
        .find(|(p, _)| p == point)
        .map(|(_, arg)| arg.clone())
}

/// Does an armed entry's path filter accept this path? Bare entries
/// accept everything.
fn path_matches(arg: &Option<String>, path: &std::path::Path) -> bool {
    match arg {
        None => true,
        Some(filter) => path.to_string_lossy().contains(filter.as_str()),
    }
}

/// Fail with an injected-fault error if `point` is armed and its path
/// filter (if any) matches `path`.
pub fn fail_point_at(point: &str, path: &std::path::Path) -> Result<()> {
    if let Some(arg) = armed(point) {
        if path_matches(&arg, path) {
            bail!("fault injected: {point}");
        }
    }
    Ok(())
}

/// Sleep for the armed delay (in milliseconds) if `point` is armed with a
/// numeric argument; `point` alone defaults to 10ms.
pub fn delay_point(point: &str) {
    if let Some(arg) = armed(point) {
        let ms = arg.and_then(|a| a.parse::<u64>().ok()).unwrap_or(10);
        std::thread::sleep(std::time::Duration::from_millis(ms));
    }
}

/// If `point` is armed and its path filter matches, truncate `path` to
/// half its current length (simulating a torn write / bit rot that
/// invalidates the tail of the file). Returns whether the fault fired.
pub fn maybe_truncate(point: &str, path: &std::path::Path) -> bool {
    match armed(point) {
        None => return false,
        Some(arg) => {
            if !path_matches(&arg, path) {
                return false;
            }
        }
    }
    if let Ok(f) = std::fs::OpenOptions::new().write(true).open(path) {
        if let Ok(meta) = f.metadata() {
            let _ = f.set_len(meta.len() / 2);
            let _ = f.sync_all();
        }
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;

    // Fault state is process-global and cargo runs tests concurrently, so
    // this self-test only arms entries carrying a path filter no other
    // test's paths can contain.
    #[test]
    fn arm_parse_and_disarm_roundtrip() {
        let _x = exclusive();
        arm("manifest_tear=fault-selftest, finalize_truncate=fault-selftest ,,");
        let elsewhere = std::path::Path::new("/tmp/anywhere");
        let here = std::path::Path::new("/tmp/fault-selftest/store");
        assert_eq!(armed("manifest_tear"), Some(Some("fault-selftest".to_string())));
        assert_eq!(
            armed("finalize_truncate"),
            Some(Some("fault-selftest".to_string()))
        );
        assert_eq!(armed("publish_delay"), None);
        // Path filters scope a fault to matching paths only.
        assert!(fail_point_at("manifest_tear", elsewhere).is_ok());
        let err = fail_point_at("manifest_tear", here).unwrap_err().to_string();
        assert!(err.contains("fault injected"), "got: {err}");
        // Truncation on a missing file is a no-op beyond reporting `fired`.
        assert!(!maybe_truncate("finalize_truncate", elsewhere));
        assert!(maybe_truncate("finalize_truncate", here));
        disarm();
        assert_eq!(armed("manifest_tear"), None);
        assert!(fail_point_at("manifest_tear", here).is_ok());
        assert!(!maybe_truncate("finalize_truncate", here));
    }
}
