//! Quantized (v2) gradient store: symmetric int8 rows with per-block f32
//! scales — the serving-path cousin of the sketched/compressed attribution
//! readouts in PAPERS.md. A quantized copy of a store is ~4x smaller and
//! its scan moves ~4x fewer bytes, which is the whole game for the paper's
//! "write once, scan forever" cost trade (§4.2): scan bandwidth IS query
//! throughput.
//!
//! Layout (one directory per shard, mirroring the v1 two-file pattern):
//!
//! ```text
//! <dir>/codes.bin    header(32B) + rows * k * i8 codes (row-major)
//! <dir>/scales.bin   rows * ceil(k/64) * f32 per-block scales
//! <dir>/ids.bin      rows * u64 data-ids (identical to v1)
//! ```
//!
//! Header: magic "LOGRAQNT", u32 version=2, u32 k, u64 rows, u32 block,
//! 4B pad. Like v1, the writer's `finalize` patches the row count in
//! `codes.bin` — the durability authority; `scales.bin`/`ids.bin` lengths
//! are validated against it at open.
//!
//! Codec: each 64-value block stores `scale = max|v| / 127` and codes
//! `round(v / scale)` in [-127, 127]. Reconstruction error is at most
//! `scale / 2` per value. Dots between two quantized rows accumulate the
//! i8×i8 products in i32 per block (|sum| ≤ 64·127² ≪ i32::MAX), then
//! combine blocks as `a_scale · b_scale · sum` in f32 — the stage-1 kernel
//! of the two-stage query engine
//! ([`crate::valuation::TwoStageEngine`]).
//!
//! A sharded quantized store is the same `shards.json` fabric as the f32
//! layout with `"codec": "int8"` in the manifest; [`QuantShardedStore`]
//! mirrors [`ShardedStore`]'s global-row contract.

use std::fs::{File, OpenOptions};
use std::io::{BufWriter, Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};

use anyhow::{anyhow, ensure, Context, Result};

use super::mmap::Mmap;
use super::shards::{ShardManifest, ShardedStore, StoreCodec, SHARD_MANIFEST};

const MAGIC: &[u8; 8] = b"LOGRAQNT";
const VERSION: u32 = 2;
const HEADER_LEN: usize = 32;

/// Values per quantization block (one f32 scale each) — defined by the
/// scan-kernel subsystem, which owns the block-dot microkernels.
pub const QUANT_BLOCK: usize = crate::linalg::kernels::Q8_BLOCK;

/// Code file name inside a quantized store directory.
pub const QUANT_CODES_FILE: &str = "codes.bin";

/// Scale blocks per row of width `k`.
pub fn blocks_of(k: usize) -> usize {
    k.div_ceil(QUANT_BLOCK)
}

fn header_bytes(k: u32, rows: u64) -> [u8; HEADER_LEN] {
    let mut h = [0u8; HEADER_LEN];
    h[..8].copy_from_slice(MAGIC);
    h[8..12].copy_from_slice(&VERSION.to_le_bytes());
    h[12..16].copy_from_slice(&k.to_le_bytes());
    h[16..24].copy_from_slice(&rows.to_le_bytes());
    h[24..28].copy_from_slice(&(QUANT_BLOCK as u32).to_le_bytes());
    h
}

/// Read (k, rows) from a `codes.bin` header without mapping the file
/// (manifest reconciliation for int8 fabrics).
pub fn read_quant_header(path: &Path) -> Result<(usize, u64)> {
    let mut f = File::open(path).with_context(|| format!("open {}", path.display()))?;
    let mut h = [0u8; HEADER_LEN];
    f.read_exact(&mut h).with_context(|| format!("header of {}", path.display()))?;
    ensure!(&h[..8] == MAGIC, "bad quant store magic in {}", path.display());
    let k = u32::from_le_bytes(h[12..16].try_into().unwrap()) as usize;
    let rows = u64::from_le_bytes(h[16..24].try_into().unwrap());
    Ok((k, rows))
}

// ------------------------------------------------------------------ codec

/// Quantize one row into `codes` (len k) and `scales` (len blocks_of(k)).
pub fn quantize_row(row: &[f32], codes: &mut [i8], scales: &mut [f32]) {
    debug_assert_eq!(codes.len(), row.len());
    debug_assert_eq!(scales.len(), blocks_of(row.len()));
    for (b, block) in row.chunks(QUANT_BLOCK).enumerate() {
        let amax = block.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        let scale = amax / 127.0;
        scales[b] = scale;
        let inv = if scale > 0.0 { 1.0 / scale } else { 0.0 };
        let out = &mut codes[b * QUANT_BLOCK..b * QUANT_BLOCK + block.len()];
        for (c, &v) in out.iter_mut().zip(block) {
            *c = (v * inv).round().clamp(-127.0, 127.0) as i8;
        }
    }
}

/// Quantize `n` row-major rows of width `k`: ([n*k] codes, [n*blocks] scales).
pub fn quantize_rows(rows: &[f32], n: usize, k: usize) -> (Vec<i8>, Vec<f32>) {
    assert_eq!(rows.len(), n * k);
    let blocks = blocks_of(k);
    let mut codes = vec![0i8; n * k];
    let mut scales = vec![0.0f32; n * blocks];
    for r in 0..n {
        quantize_row(
            &rows[r * k..(r + 1) * k],
            &mut codes[r * k..(r + 1) * k],
            &mut scales[r * blocks..(r + 1) * blocks],
        );
    }
    (codes, scales)
}

/// Reconstruct one quantized row into `out` (len k).
pub fn dequantize_row(codes: &[i8], scales: &[f32], out: &mut [f32]) {
    debug_assert_eq!(out.len(), codes.len());
    for (b, block) in codes.chunks(QUANT_BLOCK).enumerate() {
        let scale = scales[b];
        let dst = &mut out[b * QUANT_BLOCK..b * QUANT_BLOCK + block.len()];
        for (o, &c) in dst.iter_mut().zip(block) {
            *o = c as f32 * scale;
        }
    }
}

/// Approximate dot of two quantized rows: per-block i32 code dot, combined
/// through both scales in f32. This is the REFERENCE kernel: block sums
/// are exact integers and the combine order is fixed, so the dispatched
/// scan kernel ([`crate::linalg::kernels::scan_q8_into`], which the
/// two-stage engine's stage 1 actually runs) must — and does — reproduce
/// it bit-identically on every arm (property-tested in
/// `rust/tests/kernels.rs`).
#[inline]
pub fn dot_q8(a_codes: &[i8], a_scales: &[f32], b_codes: &[i8], b_scales: &[f32]) -> f32 {
    debug_assert_eq!(a_codes.len(), b_codes.len());
    let mut acc = 0.0f32;
    let blocks = a_codes.chunks(QUANT_BLOCK).zip(b_codes.chunks(QUANT_BLOCK));
    for (b, (ab, bb)) in blocks.enumerate() {
        let mut s = 0i32;
        for (&x, &y) in ab.iter().zip(bb) {
            s += x as i32 * y as i32;
        }
        acc += a_scales[b] * b_scales[b] * s as f32;
    }
    acc
}

/// Score `nt` quantized test rows against `len` quantized train rows:
/// row-major [nt, len] approximate scores (the int8 twin of the f32 scan
/// kernel). Allocating convenience wrapper over the dispatched
/// [`crate::linalg::kernels::scan_q8_into`]; the scan engines call the
/// `_into` form directly with per-worker scratch so the steady-state scan
/// allocates nothing per chunk.
#[allow(clippy::too_many_arguments)]
pub fn scan_scores_q8(
    t_codes: &[i8],
    t_scales: &[f32],
    nt: usize,
    codes: &[i8],
    scales: &[f32],
    len: usize,
    k: usize,
) -> Vec<f32> {
    let mut out = vec![0.0f32; nt * len];
    crate::linalg::kernels::scan_q8_into(t_codes, t_scales, nt, codes, scales, len, k, &mut out);
    out
}

// ----------------------------------------------------------------- writer

/// Append-only writer for one quantized store directory. Quantizes f32
/// rows on the way in; `finalize` patches the `codes.bin` header row count
/// (same crash story as [`super::GradStoreWriter`]).
pub struct QuantWriter {
    codes: BufWriter<File>,
    scales: BufWriter<File>,
    ids: BufWriter<File>,
    dir: PathBuf,
    k: usize,
    rows: u64,
}

impl QuantWriter {
    pub fn create(dir: &Path, k: usize) -> Result<Self> {
        ensure!(k > 0, "quant store needs k > 0");
        std::fs::create_dir_all(dir)?;
        let mut cf = BufWriter::new(File::create(dir.join(QUANT_CODES_FILE))?);
        cf.write_all(&header_bytes(k as u32, 0))?;
        let sf = BufWriter::new(File::create(dir.join("scales.bin"))?);
        let ifile = BufWriter::new(File::create(dir.join("ids.bin"))?);
        Ok(QuantWriter { codes: cf, scales: sf, ids: ifile, dir: dir.to_path_buf(), k, rows: 0 })
    }

    /// Quantize and append a batch: `rows` is row-major [n, k] f32.
    pub fn append(&mut self, ids: &[u64], rows: &[f32]) -> Result<()> {
        if rows.len() != ids.len() * self.k {
            return Err(anyhow!(
                "append: {} ids x k={} needs {} floats, got {}",
                ids.len(),
                self.k,
                ids.len() * self.k,
                rows.len()
            ));
        }
        let (codes, scales) = quantize_rows(rows, ids.len(), self.k);
        // i8 and u8 share layout; f32 bytes come from this machine.
        let code_bytes = unsafe {
            std::slice::from_raw_parts(codes.as_ptr() as *const u8, codes.len())
        };
        let scale_bytes = unsafe {
            std::slice::from_raw_parts(scales.as_ptr() as *const u8, scales.len() * 4)
        };
        self.codes.write_all(code_bytes)?;
        self.scales.write_all(scale_bytes)?;
        for &id in ids {
            self.ids.write_all(&id.to_le_bytes())?;
        }
        self.rows += ids.len() as u64;
        Ok(())
    }

    pub fn rows(&self) -> u64 {
        self.rows
    }

    /// Flush buffers and patch the `codes.bin` header row count.
    pub fn finalize(mut self) -> Result<u64> {
        self.codes.flush()?;
        self.scales.flush()?;
        self.ids.flush()?;
        let mut f = OpenOptions::new().write(true).open(self.dir.join(QUANT_CODES_FILE))?;
        f.seek(SeekFrom::Start(0))?;
        f.write_all(&header_bytes(self.k as u32, self.rows))?;
        f.sync_all()?;
        // Fault point: silent int8 sidecar damage (bit rot / lost pages)
        // that finalize does NOT notice — `QuantStore::open`'s length
        // checks must catch it at the next reload.
        super::fault::maybe_truncate("quant_corrupt", &self.dir.join(QUANT_CODES_FILE));
        Ok(self.rows)
    }
}

// ------------------------------------------------------------------ store

/// Read view over a finalized quantized store directory (one shard).
pub struct QuantStore {
    codes: Mmap,
    scales: Mmap,
    ids: Mmap,
    k: usize,
    blocks: usize,
    rows: usize,
}

impl QuantStore {
    pub fn open(dir: &Path) -> Result<Self> {
        let codes = Mmap::open(&dir.join(QUANT_CODES_FILE))
            .with_context(|| format!("quant store {}", dir.display()))?;
        let bytes = codes.as_slice();
        if bytes.len() < HEADER_LEN || &bytes[..8] != MAGIC {
            return Err(anyhow!("bad quant store header in {}", dir.display()));
        }
        let version = u32::from_le_bytes(bytes[8..12].try_into().unwrap());
        ensure!(version == VERSION, "quant store version {version} unsupported");
        let k = u32::from_le_bytes(bytes[12..16].try_into().unwrap()) as usize;
        ensure!(
            k > 0,
            "quant store {} header declares k=0 (corrupt or unfinalized create)",
            dir.display()
        );
        let rows = u64::from_le_bytes(bytes[16..24].try_into().unwrap()) as usize;
        let block = u32::from_le_bytes(bytes[24..28].try_into().unwrap()) as usize;
        ensure!(
            block == QUANT_BLOCK,
            "quant store block {block} != supported {QUANT_BLOCK}"
        );
        let need = HEADER_LEN + rows * k;
        ensure!(
            bytes.len() >= need,
            "quant store truncated: need {need} bytes, have {}",
            bytes.len()
        );
        let blocks = blocks_of(k);
        let scales = Mmap::open(&dir.join("scales.bin"))?;
        ensure!(
            scales.len() >= rows * blocks * 4,
            "scales file truncated: {rows} rows need {} bytes, have {}",
            rows * blocks * 4,
            scales.len()
        );
        let ids = Mmap::open(&dir.join("ids.bin"))?;
        ensure!(
            ids.len() >= rows * 8,
            "ids file truncated: {rows} rows need {} bytes, have {}",
            rows * 8,
            ids.len()
        );
        codes.advise_sequential();
        scales.advise_sequential();
        Ok(QuantStore { codes, scales, ids, k, blocks, rows })
    }

    pub fn k(&self) -> usize {
        self.k
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Scale blocks per row.
    pub fn blocks(&self) -> usize {
        self.blocks
    }

    /// Raw i8 codes of rows [start, start+len).
    pub fn codes_chunk(&self, start: usize, len: usize) -> &[i8] {
        assert!(start + len <= self.rows, "codes chunk out of range");
        let byte_off = HEADER_LEN + start * self.k;
        let bytes = &self.codes.as_slice()[byte_off..byte_off + len * self.k];
        // i8 and u8 have identical size/alignment.
        unsafe { std::slice::from_raw_parts(bytes.as_ptr() as *const i8, bytes.len()) }
    }

    /// Raw f32 block scales of rows [start, start+len).
    pub fn scales_chunk(&self, start: usize, len: usize) -> &[f32] {
        assert!(start + len <= self.rows, "scales chunk out of range");
        let byte_off = start * self.blocks * 4;
        let bytes = &self.scales.as_slice()[byte_off..byte_off + len * self.blocks * 4];
        // scales.bin has no header; offsets stay 4-byte aligned.
        unsafe {
            std::slice::from_raw_parts(bytes.as_ptr() as *const f32, len * self.blocks)
        }
    }

    /// Data id of row i (same encoding as the v1 store).
    pub fn id(&self, i: usize) -> u64 {
        assert!(i < self.rows);
        let b = &self.ids.as_slice()[i * 8..i * 8 + 8];
        u64::from_le_bytes(b.try_into().unwrap())
    }

    /// Reconstructed f32 row i (tests and debugging; the serving path
    /// rescores against the exact store instead).
    pub fn dequant_row(&self, i: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; self.k];
        dequantize_row(self.codes_chunk(i, 1), self.scales_chunk(i, 1), &mut out);
        out
    }

    /// Prefetch hint for rows [start, start+len) on both data files.
    pub fn prefetch(&self, start: usize, len: usize) {
        self.codes.advise_willneed(HEADER_LEN + start * self.k, len * self.k);
        self.scales.advise_willneed(start * self.blocks * 4, len * self.blocks * 4);
    }

    /// Total stored bytes (Table-1 "Storage" column).
    pub fn storage_bytes(&self) -> u64 {
        (self.codes.len() + self.scales.len() + self.ids.len()) as u64
    }

    /// Bytes of `codes.bin` (header + int8 rows) — the `store stat`
    /// per-component breakdown.
    pub fn codes_bytes(&self) -> u64 {
        self.codes.len() as u64
    }

    /// Bytes of `scales.bin`.
    pub fn scales_bytes(&self) -> u64 {
        self.scales.len() as u64
    }

    /// Bytes of `ids.bin`.
    pub fn ids_bytes(&self) -> u64 {
        self.ids.len() as u64
    }
}

// --------------------------------------------------------- sharded fabric

/// Read view over a sharded quantized store — or a single quantized
/// directory, which opens as a 1-shard fabric. Mirrors
/// [`ShardedStore`]'s global-row contract over [`QuantStore`] shards.
pub struct QuantShardedStore {
    shards: Vec<QuantStore>,
    offsets: Vec<usize>,
    k: usize,
}

impl QuantShardedStore {
    pub fn open(dir: &Path) -> Result<Self> {
        if dir.join(SHARD_MANIFEST).exists() {
            let man = ShardManifest::load(dir)?;
            ensure!(
                man.codec == StoreCodec::Int8,
                "store {} uses the {} codec; open it with ShardedStore",
                dir.display(),
                man.codec.as_str()
            );
            let mut shards = Vec::with_capacity(man.n_shards());
            for (i, name) in man.shard_dirs.iter().enumerate() {
                let sdir = dir.join(name);
                let s = QuantStore::open(&sdir).map_err(|e| {
                    let actual = read_quant_header(&sdir.join(QUANT_CODES_FILE))
                        .map(|(_, rows)| rows.to_string())
                        .unwrap_or_else(|_| "unreadable".to_string());
                    e.context(format!(
                        "shard {name} at {} failed validation: manifest expects {} rows, \
                         header reports {actual}",
                        sdir.display(),
                        man.shard_rows[i]
                    ))
                })?;
                ensure!(
                    s.k() == man.k,
                    "shard {name}: k={} disagrees with manifest k={}",
                    s.k(),
                    man.k
                );
                shards.push(s);
            }
            Ok(Self::from_shards(shards))
        } else {
            Ok(Self::from_shards(vec![QuantStore::open(dir)?]))
        }
    }

    fn from_shards(shards: Vec<QuantStore>) -> Self {
        let k = shards[0].k();
        let mut offsets = Vec::with_capacity(shards.len() + 1);
        let mut acc = 0usize;
        offsets.push(0);
        for s in &shards {
            acc += s.rows();
            offsets.push(acc);
        }
        QuantShardedStore { shards, offsets, k }
    }

    pub fn k(&self) -> usize {
        self.k
    }

    pub fn rows(&self) -> usize {
        *self.offsets.last().unwrap()
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    pub fn shard(&self, i: usize) -> &QuantStore {
        &self.shards[i]
    }

    /// First global row of shard i.
    pub fn shard_start(&self, i: usize) -> usize {
        self.offsets[i]
    }

    /// Global row -> (shard index, local row). Skips empty shards.
    pub fn locate(&self, row: usize) -> (usize, usize) {
        assert!(row < self.rows(), "row {row} out of range");
        let s = self.offsets.partition_point(|&o| o <= row) - 1;
        (s, row - self.offsets[s])
    }

    /// Data id of global row i.
    pub fn id(&self, i: usize) -> u64 {
        let (s, local) = self.locate(i);
        self.shards[s].id(local)
    }

    /// Total stored bytes across shards.
    pub fn storage_bytes(&self) -> u64 {
        self.shards.iter().map(QuantStore::storage_bytes).sum()
    }
}

// ------------------------------------------------------------- conversion

/// Convert any f32 store (v1 or sharded) into a quantized copy at `dst`,
/// preserving shard structure, global row order, and data ids. The source
/// stays untouched — serve stage-1 scans from `dst` and exact rescoring
/// from `src`.
pub fn quantize_store(src: &Path, dst: &Path) -> Result<ShardManifest> {
    let store = ShardedStore::open(src)?;
    let k = store.k();
    ensure!(k > 0, "cannot quantize a store with k=0");
    std::fs::create_dir_all(dst)?;
    // Record where the exact f32 source lives (absolute when resolvable)
    // so `Valuator::open(dst)` can pair the stage-2 rescore substrate
    // without the caller passing both directories. The manifest parser's
    // string subset has no escapes — skip the pointer for exotic paths.
    let rescore_dir = src
        .canonicalize()
        .unwrap_or_else(|_| src.to_path_buf())
        .to_str()
        .filter(|s| !s.contains('"') && !s.contains('\\'))
        .map(str::to_string);
    let shard_dirs: Vec<String> =
        (0..store.n_shards()).map(|i| format!("shard-{i:04}")).collect();
    // Create every shard (dir + zero-row header) BEFORE the manifest, then
    // write the zero-row manifest, so the destination is openable from the
    // first byte and a mid-conversion crash leaves a valid (partial) store
    // — same convention as ShardedWriter::create.
    let mut writers = Vec::with_capacity(store.n_shards());
    for name in &shard_dirs {
        writers.push(QuantWriter::create(&dst.join(name), k)?);
    }
    ShardManifest {
        k,
        codec: StoreCodec::Int8,
        generation: 0,
        rescore_dir: rescore_dir.clone(),
        index: None,
        shard_dirs: shard_dirs.clone(),
        shard_rows: vec![0; store.n_shards()],
    }
    .save(dst)?;
    let mut shard_rows = Vec::with_capacity(store.n_shards());
    for (si, mut w) in writers.into_iter().enumerate() {
        convert_shard(&store, si, &mut w)?;
        shard_rows.push(w.finalize()?);
    }
    let man = ShardManifest {
        k,
        codec: StoreCodec::Int8,
        generation: 1,
        rescore_dir,
        index: None,
        shard_dirs,
        shard_rows,
    };
    man.save(dst)?;
    Ok(man)
}

/// Stream one f32 shard into a quant writer in bounded chunks.
fn convert_shard(store: &ShardedStore, si: usize, w: &mut QuantWriter) -> Result<()> {
    let shard = store.shard(si);
    let rows = shard.rows();
    let mut at = 0usize;
    while at < rows {
        let len = 1024.min(rows - at);
        let ids: Vec<u64> = (at..at + len).map(|r| shard.id(r)).collect();
        w.append(&ids, shard.chunk(at, len))?;
        at += len;
    }
    Ok(())
}

/// What an incremental quantize pass did.
#[derive(Clone, Copy, Debug, Default)]
pub struct QuantizeReport {
    /// Shards (re)converted this pass.
    pub converted: usize,
    /// Shards whose int8 mirror already matched the f32 source row count.
    pub skipped: usize,
}

/// Incremental [`quantize_store`]: bring the int8 mirror at `dst` up to
/// date with a (possibly grown) f32 store at `src`, skipping every shard
/// whose mirror already exists with a matching row count. This is how the
/// quantized fabric tracks a live-growing f32 fabric without re-encoding
/// the whole corpus per append.
///
/// New shards carry no IVF sidecars; the manifest's `index` advertisement
/// is preserved, so an indexed store keeps serving with the unindexed
/// shards on the per-shard full-scan fallback until `logra store index`
/// is re-run. The destination generation advances only when something
/// actually changed.
pub fn quantize_store_incremental(
    src: &Path,
    dst: &Path,
) -> Result<(ShardManifest, QuantizeReport)> {
    if !dst.join(SHARD_MANIFEST).exists() {
        let man = quantize_store(src, dst)?;
        let converted = man.n_shards();
        return Ok((man, QuantizeReport { converted, skipped: 0 }));
    }
    let store = ShardedStore::open(src)?;
    let k = store.k();
    let man = ShardManifest::load(dst)?;
    ensure!(
        man.codec == StoreCodec::Int8,
        "incremental quantize target {} is not an int8 store",
        dst.display()
    );
    ensure!(
        man.k == k,
        "incremental quantize: source k={k} disagrees with target k={}",
        man.k
    );
    let mut report = QuantizeReport::default();
    let mut shard_dirs = Vec::with_capacity(store.n_shards());
    let mut shard_rows = Vec::with_capacity(store.n_shards());
    for si in 0..store.n_shards() {
        let name = super::shards::shard_dir_name(si);
        let src_rows = store.shard(si).rows() as u64;
        let up_to_date = man.shard_dirs.get(si) == Some(&name)
            && read_quant_header(&dst.join(&name).join(QUANT_CODES_FILE))
                .map(|(qk, qrows)| qk == k && qrows == src_rows)
                .unwrap_or(false);
        if up_to_date {
            report.skipped += 1;
        } else {
            // Rebuild this shard's mirror from scratch; any IVF sidecars
            // in the old directory would be stale and go with it.
            let sdir = dst.join(&name);
            let _ = std::fs::remove_dir_all(&sdir);
            let mut w = QuantWriter::create(&sdir, k)?;
            convert_shard(&store, si, &mut w)?;
            w.finalize()?;
            report.converted += 1;
        }
        shard_dirs.push(name);
        shard_rows.push(src_rows);
    }
    if report.converted == 0 && shard_dirs == man.shard_dirs {
        return Ok((man, report));
    }
    let man = ShardManifest {
        k,
        codec: StoreCodec::Int8,
        generation: man.generation + 1,
        rescore_dir: man.rescore_dir,
        index: man.index,
        shard_dirs,
        shard_rows,
    };
    man.save(dst)?;
    Ok((man, report))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::store::GradStoreWriter;
    use crate::util::rng::Pcg32;

    fn tmpdir(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("logra-quant-tests").join(name);
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn codec_roundtrip_error_bounded() {
        let mut rng = Pcg32::seeded(1);
        for &k in &[1usize, 63, 64, 65, 192] {
            let mut row = vec![0.0f32; k];
            rng.fill_normal(&mut row, 2.0);
            let mut codes = vec![0i8; k];
            let mut scales = vec![0.0f32; blocks_of(k)];
            quantize_row(&row, &mut codes, &mut scales);
            let mut back = vec![0.0f32; k];
            dequantize_row(&codes, &scales, &mut back);
            for (i, (&v, &r)) in row.iter().zip(&back).enumerate() {
                let b = i / QUANT_BLOCK;
                // Round-to-nearest: at most half a quantization step off.
                let bound = scales[b] * 0.5 + 1e-7;
                assert!(
                    (v - r).abs() <= bound,
                    "k={k} i={i}: |{v} - {r}| > {bound}"
                );
            }
        }
    }

    #[test]
    fn zero_block_quantizes_to_zero() {
        let row = vec![0.0f32; 70];
        let mut codes = vec![1i8; 70];
        let mut scales = vec![9.0f32; blocks_of(70)];
        quantize_row(&row, &mut codes, &mut scales);
        assert!(codes.iter().all(|&c| c == 0));
        assert!(scales.iter().all(|&s| s == 0.0));
        let mut back = vec![1.0f32; 70];
        dequantize_row(&codes, &scales, &mut back);
        assert!(back.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn dot_q8_tracks_exact_dot() {
        let mut rng = Pcg32::seeded(3);
        let k = 192;
        for _ in 0..20 {
            let mut a = vec![0.0f32; k];
            let mut b = vec![0.0f32; k];
            rng.fill_normal(&mut a, 1.0);
            rng.fill_normal(&mut b, 1.0);
            let (ac, asc) = quantize_rows(&a, 1, k);
            let (bc, bsc) = quantize_rows(&b, 1, k);
            let approx = dot_q8(&ac, &asc, &bc, &bsc);
            let exact = crate::linalg::dot(&a, &b);
            // Per-value error ≤ scale/2 ≈ amax/254; dot error concentrates
            // around sqrt(k) * O(amax²/254). Loose but honest bound:
            let bound = 0.05 * (k as f32).sqrt() * 4.0;
            assert!(
                (approx - exact).abs() <= bound,
                "|{approx} - {exact}| > {bound}"
            );
        }
    }

    #[test]
    fn writer_store_roundtrip() {
        let dir = tmpdir("roundtrip");
        let k = 70; // exercises a partial trailing block
        let n = 37;
        let mut rng = Pcg32::seeded(5);
        let mut rows = vec![0.0f32; n * k];
        rng.fill_normal(&mut rows, 1.0);
        let ids: Vec<u64> = (0..n as u64).map(|i| i * 3 + 7).collect();
        let mut w = QuantWriter::create(&dir, k).unwrap();
        // Split into two batches to exercise append boundaries.
        w.append(&ids[..10], &rows[..10 * k]).unwrap();
        w.append(&ids[10..], &rows[10 * k..]).unwrap();
        assert_eq!(w.finalize().unwrap(), n as u64);

        let s = QuantStore::open(&dir).unwrap();
        assert_eq!(s.rows(), n);
        assert_eq!(s.k(), k);
        assert_eq!(s.blocks(), 2);
        let (want_codes, want_scales) = quantize_rows(&rows, n, k);
        assert_eq!(s.codes_chunk(0, n), &want_codes[..]);
        assert_eq!(s.scales_chunk(0, n), &want_scales[..]);
        for i in 0..n {
            assert_eq!(s.id(i), ids[i]);
            let deq = s.dequant_row(i);
            for (j, (&v, &r)) in rows[i * k..(i + 1) * k].iter().zip(&deq).enumerate() {
                let bound = want_scales[i * 2 + j / QUANT_BLOCK] * 0.5 + 1e-7;
                assert!((v - r).abs() <= bound);
            }
        }
        s.prefetch(0, n);
    }

    #[test]
    fn unfinalized_store_reports_zero_rows() {
        let dir = tmpdir("unfinalized");
        let mut w = QuantWriter::create(&dir, 8).unwrap();
        w.append(&[1], &[1.0; 8]).unwrap();
        drop(w); // no finalize: header still says 0 rows
        let s = QuantStore::open(&dir).unwrap();
        assert_eq!(s.rows(), 0);
    }

    #[test]
    fn corrupt_and_zero_k_rejected() {
        let dir = tmpdir("corrupt");
        std::fs::write(dir.join(QUANT_CODES_FILE), b"NOTMAGICxxxxxxxxxxxxxxxxxxxxxxxx")
            .unwrap();
        std::fs::write(dir.join("scales.bin"), b"").unwrap();
        std::fs::write(dir.join("ids.bin"), b"").unwrap();
        assert!(QuantStore::open(&dir).is_err());

        let dir = tmpdir("zero-k");
        std::fs::write(dir.join(QUANT_CODES_FILE), header_bytes(0, 0)).unwrap();
        std::fs::write(dir.join("scales.bin"), b"").unwrap();
        std::fs::write(dir.join("ids.bin"), b"").unwrap();
        let err = QuantStore::open(&dir).unwrap_err().to_string();
        assert!(err.contains("k=0"), "unexpected error: {err}");
    }

    #[test]
    fn quantize_store_preserves_order_and_shrinks() {
        let src = tmpdir("convert-src");
        let k = 192;
        let n = 256;
        let mut rng = Pcg32::seeded(9);
        let mut rows = vec![0.0f32; n * k];
        rng.fill_normal(&mut rows, 1.0);
        let ids: Vec<u64> = (0..n as u64).map(|i| 5000 - i * 2).collect();
        let mut w = GradStoreWriter::create(&src, k).unwrap();
        w.append(&ids, &rows).unwrap();
        w.finalize().unwrap();

        // v1 source -> 1-shard quantized fabric.
        let dst = tmpdir("convert-dst");
        let man = quantize_store(&src, &dst).unwrap();
        assert_eq!(man.codec, StoreCodec::Int8);
        assert_eq!(man.total_rows(), n as u64);
        let q = QuantShardedStore::open(&dst).unwrap();
        assert_eq!(q.rows(), n);
        assert_eq!(q.k(), k);
        for g in 0..n {
            assert_eq!(q.id(g), ids[g]);
        }

        // ~4x smaller: f32 rows are k*4 bytes, quantized k + blocks*4.
        let f32_store = crate::store::ShardedStore::open(&src).unwrap();
        let ratio = f32_store.storage_bytes() as f64 / q.storage_bytes() as f64;
        assert!(ratio > 3.0, "compression ratio only {ratio:.2}x");

        // Sharded source keeps its shard structure.
        let sharded_src = tmpdir("convert-sharded-src");
        crate::store::shard_store(&src, &sharded_src, 3).unwrap();
        let sharded_dst = tmpdir("convert-sharded-dst");
        let man = quantize_store(&sharded_src, &sharded_dst).unwrap();
        assert_eq!(man.n_shards(), 3);
        let q = QuantShardedStore::open(&sharded_dst).unwrap();
        assert_eq!(q.n_shards(), 3);
        assert_eq!(q.rows(), n);
        for g in 0..n {
            assert_eq!(q.id(g), ids[g]);
        }

        // Codec mismatches produce clear errors in both directions.
        assert!(crate::store::ShardedStore::open(&sharded_dst).is_err());
        assert!(QuantShardedStore::open(&sharded_src).is_err());
        // And re-quantizing a quantized store is rejected cleanly.
        assert!(quantize_store(&sharded_dst, &tmpdir("convert-twice")).is_err());
    }
}
