//! LoGra as a [`Valuator`] (the paper's method, PCA or random init),
//! wired through the real production path: logging pipeline -> gradient
//! store -> Fisher blocks -> query engine.

use std::path::PathBuf;

use anyhow::Result;

use crate::baselines::Valuator;
use crate::coordinator::{fit_kfac, projected_grads, run_logging, LoggingOptions};
use crate::hessian::{pca_projections, random_projections, Preconditioner};
use crate::linalg::Matrix;
use crate::model::dataset::Dataset;
use crate::runtime::Runtime;
use crate::store::GradStore;
use crate::util::rng::Pcg32;
use crate::valuation::{Normalization, QueryEngine};

/// Projection initialization scheme (§3.2 / Figure 4's two LoGra rows).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LograInit {
    Random,
    Pca,
}

pub struct LograValuator<'a> {
    rt: &'a Runtime,
    train: &'a Dataset<'a>,
    test: &'a Dataset<'a>,
    params: &'a [f32],
    proj: Vec<f32>,
    store: GradStore,
    precond: Preconditioner,
    pub norm: Normalization,
    label: String,
}

impl<'a> LograValuator<'a> {
    /// Run the full logging phase into `store_dir`.
    #[allow(clippy::too_many_arguments)]
    pub fn build(
        rt: &'a Runtime,
        train: &'a Dataset<'a>,
        test: &'a Dataset<'a>,
        params: &'a [f32],
        init: LograInit,
        store_dir: PathBuf,
        damping: f32,
        seed: u64,
    ) -> Result<Self> {
        let proj = match init {
            LograInit::Random => {
                let mut rng = Pcg32::new(seed, 7);
                random_projections(&rt.manifest, &mut rng)
            }
            LograInit::Pca => {
                let kfac = fit_kfac(rt, train, params, 64)?;
                pca_projections(&rt.manifest, &kfac)
            }
        };
        let (store, hessian, _report) =
            run_logging(rt, train, params, &proj, &store_dir, &LoggingOptions::default())?;
        let precond = hessian.expect("fit_hessian on").preconditioner(damping)?;
        let label = match init {
            LograInit::Random => "logra-random",
            LograInit::Pca => "logra-pca",
        };
        Ok(LograValuator {
            rt,
            train,
            test,
            params,
            proj,
            store,
            precond,
            norm: Normalization::None,
            label: label.to_string(),
        })
    }

    pub fn engine(&self) -> QueryEngine<'_> {
        QueryEngine::new(self.rt, &self.store, &self.precond)
    }

    pub fn store(&self) -> &GradStore {
        &self.store
    }

    pub fn projection(&self) -> &[f32] {
        &self.proj
    }

    /// Raw projected gradients for test examples.
    pub fn test_grads(&self, test_indices: &[usize]) -> Result<Vec<f32>> {
        let (rows, _) =
            projected_grads(self.rt, self.test, test_indices, self.params, &self.proj)?;
        Ok(rows)
    }
}

impl Valuator for LograValuator<'_> {
    fn name(&self) -> String {
        self.label.clone()
    }

    fn values(&mut self, test_indices: &[usize]) -> Result<Matrix> {
        let g = self.test_grads(test_indices)?;
        let engine = self.engine();
        engine.values_matrix(&g, test_indices.len(), self.norm)
    }
}

// Silence dead-code warnings for fields used only via the trait object.
#[allow(dead_code)]
fn _uses(v: &LograValuator) -> usize {
    v.train.len()
}
