//! TRAK-style baseline: NAIVE low-rank gradient projection.
//!
//! Materializes full per-sample gradients, then multiplies by a dense
//! random `R ∈ R^{k×n}` — the O(b·n·k) compute and O(k·n) memory profile
//! the paper's §2 identifies as the reason TRAK is stuck at small k (its
//! 8B-scale projection matrix would be 128 TB vs LoGra's ~1 GB). The
//! influence functional form in the projected space matches LoGra's:
//! projected Fisher + damped iHVP.

use anyhow::Result;

use crate::baselines::{collect_rows, stream_rows, Valuator};
use crate::hessian::BlockHessian;
use crate::linalg::Matrix;
use crate::model::dataset::Dataset;
use crate::runtime::Runtime;
use crate::util::rng::Pcg32;

pub struct TrakValuator<'a> {
    pub rt: &'a Runtime,
    pub train: &'a Dataset<'a>,
    pub test: &'a Dataset<'a>,
    pub params: &'a [f32],
    /// Projection dimension (paper: TRAK limited to small k by memory).
    pub k: usize,
    pub damping: f32,
    pub seed: u64,
    /// Cached after the first values() call: projected train grads +
    /// preconditioner (TRAK's "featurization" pass).
    state: Option<TrakState>,
}

struct TrakState {
    train_proj: Matrix, // [n_train, k]
    precond: crate::hessian::Preconditioner,
    r: Matrix, // [k, n] — the big dense projection
}

impl<'a> TrakValuator<'a> {
    pub fn new(
        rt: &'a Runtime,
        train: &'a Dataset<'a>,
        test: &'a Dataset<'a>,
        params: &'a [f32],
        k: usize,
        damping: f32,
        seed: u64,
    ) -> Self {
        TrakValuator { rt, train, test, params, k, damping, seed, state: None }
    }

    fn featurize(&mut self) -> Result<()> {
        if self.state.is_some() {
            return Ok(());
        }
        let n = self.rt.manifest.n_params;
        let mut rng = Pcg32::new(self.seed, 31);
        // Gaussian projection scaled for isometry-in-expectation.
        let r = Matrix::random_normal(&mut rng, self.k, n, 1.0 / (self.k as f32).sqrt());
        crate::util::memory::ledger_alloc(self.k * n * 4);

        let n_train = self.train.len();
        let idx: Vec<usize> = (0..n_train).collect();
        let mut proj = Matrix::zeros(n_train, self.k);
        let mut hess = BlockHessian::single_block(self.k);
        let mut row0 = 0usize;
        stream_rows(self.rt, "full_grad", self.train, &idx, self.params, None, 0, |rows, real| {
            let g = Matrix::from_vec(real, n, rows.to_vec());
            let p = g.matmul_t(&r); // the naive O(b n k) projection
            hess.accumulate(&p.data, real);
            for t in 0..real {
                proj.data[(row0 + t) * self.k..(row0 + t + 1) * self.k]
                    .copy_from_slice(p.row(t));
            }
            row0 += real;
            Ok(())
        })?;
        let precond = hess.preconditioner(self.damping)?;
        self.state = Some(TrakState { train_proj: proj, precond, r });
        Ok(())
    }
}

impl Valuator for TrakValuator<'_> {
    fn name(&self) -> String {
        format!("trak-k{}", self.k)
    }

    fn values(&mut self, test_indices: &[usize]) -> Result<Matrix> {
        self.featurize()?;
        let st = self.state.as_ref().unwrap();
        let n = self.rt.manifest.n_params;
        let test_full = collect_rows(
            self.rt,
            "full_grad",
            self.test,
            test_indices,
            self.params,
            None,
            0,
            n,
        )?;
        let test_proj = test_full.matmul_t(&st.r); // [nt, k]
        let pre = st.precond.apply_rows(&test_proj.data, test_indices.len());
        let pre_m = Matrix::from_vec(test_indices.len(), self.k, pre);
        Ok(pre_m.matmul_t(&st.train_proj))
    }
}
