//! Data-valuation methods compared in Figure 4: LoGra (PCA / random init)
//! plus the four baselines the paper benchmarks against — gradient dot
//! product (TracIn-CP-style), TRAK-style naive low-rank projection, EKFAC
//! influence, and representation similarity.
//!
//! Every method implements [`Valuator`]: a dense value matrix
//! [n_test, n_train] that the counterfactual harness (brittleness / LDS)
//! consumes. Construction is allowed to do the method's whole "logging"
//! phase (passes over the training set); `values` should then be cheap
//! per test example — mirroring each method's real cost profile so the
//! Table-1 efficiency comparison falls out of the same code.

pub mod ekfac_if;
pub mod grad_dot;
pub mod logra_method;
pub mod rep_sim;
pub mod trak;

use anyhow::Result;

use crate::linalg::Matrix;
use crate::model::dataset::Dataset;
use crate::runtime::literal::{f32_lit, to_f32_vec};
use crate::runtime::Runtime;

pub use ekfac_if::EkfacValuator;
pub use grad_dot::GradDotValuator;
pub use logra_method::{LograInit, LograValuator};
pub use rep_sim::RepSimValuator;
pub use trak::TrakValuator;

/// A data-valuation method producing values of train examples for test
/// examples. Higher = more valuable (more positive influence).
pub trait Valuator {
    fn name(&self) -> String;

    /// Dense [test_indices.len(), n_train] value matrix.
    fn values(&mut self, test_indices: &[usize]) -> Result<Matrix>;
}

/// Stream an artifact that maps (params, *batch) -> per-sample rows
/// ([B, row_len] as output 0). Calls `sink(rows, real)` per batch with
/// pad rows already trimmed.
pub(crate) fn stream_rows(
    rt: &Runtime,
    entry: &str,
    ds: &Dataset,
    indices: &[usize],
    params: &[f32],
    extra: Option<&[f32]>,
    extra_len: usize,
    mut sink: impl FnMut(&[f32], usize) -> Result<()>,
) -> Result<()> {
    let man = &rt.manifest;
    let params_lit = f32_lit(&[man.n_params], params)?;
    let extra_lit = match extra {
        Some(e) => Some(f32_lit(&[extra_len], e)?),
        None => None,
    };
    for batch in ds.batches(indices, man.log_batch) {
        let batch_lits = batch.literals(man)?;
        let mut args: Vec<&xla::Literal> = vec![&params_lit];
        if let Some(e) = &extra_lit {
            args.push(e);
        }
        args.extend(batch_lits.iter());
        let out = rt.run_ref(entry, &args)?;
        let rows = to_f32_vec(&out[0])?;
        let row_len = rows.len() / batch.size();
        sink(&rows[..batch.real() * row_len], batch.real())?;
    }
    Ok(())
}

/// Collect streamed rows into a dense matrix [indices.len(), row_len].
pub(crate) fn collect_rows(
    rt: &Runtime,
    entry: &str,
    ds: &Dataset,
    indices: &[usize],
    params: &[f32],
    extra: Option<&[f32]>,
    extra_len: usize,
    row_len: usize,
) -> Result<Matrix> {
    let mut data = Vec::with_capacity(indices.len() * row_len);
    stream_rows(rt, entry, ds, indices, params, extra, extra_len, |rows, _real| {
        data.extend_from_slice(rows);
        Ok(())
    })?;
    Ok(Matrix::from_vec(indices.len(), row_len, data))
}
