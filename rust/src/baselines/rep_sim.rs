//! Representation-similarity baseline (Hanawa et al.): value(te, tr) =
//! cosine similarity of final hidden representations. Gradient-free —
//! cheap but blind to the loss landscape, which is exactly why Figure 4
//! shows it trailing the gradient-based methods.

use anyhow::Result;

use crate::baselines::{collect_rows, Valuator};
use crate::linalg::{cosine, Matrix};
use crate::model::dataset::Dataset;
use crate::runtime::Runtime;

pub struct RepSimValuator<'a> {
    pub rt: &'a Runtime,
    pub train: &'a Dataset<'a>,
    pub test: &'a Dataset<'a>,
    pub params: &'a [f32],
    cache: Option<Matrix>, // [n_train, d]
}

impl<'a> RepSimValuator<'a> {
    pub fn new(
        rt: &'a Runtime,
        train: &'a Dataset<'a>,
        test: &'a Dataset<'a>,
        params: &'a [f32],
    ) -> Self {
        RepSimValuator { rt, train, test, params, cache: None }
    }
}

impl Valuator for RepSimValuator<'_> {
    fn name(&self) -> String {
        "rep-sim".into()
    }

    fn values(&mut self, test_indices: &[usize]) -> Result<Matrix> {
        let d = self.rt.manifest.repr_dim;
        if self.cache.is_none() {
            let idx: Vec<usize> = (0..self.train.len()).collect();
            self.cache = Some(collect_rows(
                self.rt, "reprs", self.train, &idx, self.params, None, 0, d,
            )?);
        }
        let train_r = self.cache.as_ref().unwrap();
        let test_r = collect_rows(
            self.rt, "reprs", self.test, test_indices, self.params, None, 0, d,
        )?;
        let mut out = Matrix::zeros(test_indices.len(), self.train.len());
        for t in 0..test_indices.len() {
            for j in 0..self.train.len() {
                out.data[t * self.train.len() + j] =
                    cosine(test_r.row(t), train_r.row(j));
            }
        }
        Ok(out)
    }
}
