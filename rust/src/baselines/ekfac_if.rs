//! EKFAC influence baseline (Grosse et al. 2023) — the paper's strongest
//! and most expensive competitor.
//!
//! Logging: fit KFAC factors, eigendecompose, fit corrected eigenvalues
//! from rotated per-sample gradients. Querying: because full-rank rotated
//! gradients are too large to store, EVERY query batch recomputes every
//! train gradient (the Table-1 cost profile: throughput collapses, memory
//! stays high). Scores: <precondition(rot(g_te)), rot(g_tr)>.

use anyhow::Result;

use crate::baselines::{collect_rows, stream_rows, Valuator};
use crate::coordinator::fit_kfac;
use crate::hessian::{Ekfac, KfacFactors};
use crate::linalg::Matrix;
use crate::model::dataset::Dataset;
use crate::runtime::Runtime;

pub struct EkfacValuator<'a> {
    pub rt: &'a Runtime,
    pub train: &'a Dataset<'a>,
    pub test: &'a Dataset<'a>,
    pub params: &'a [f32],
    state: Option<Ekfac>,
}

impl<'a> EkfacValuator<'a> {
    pub fn new(
        rt: &'a Runtime,
        train: &'a Dataset<'a>,
        test: &'a Dataset<'a>,
        params: &'a [f32],
    ) -> Self {
        EkfacValuator { rt, train, test, params, state: None }
    }

    /// KFAC fit + eigendecomposition + corrected-eigenvalue fit
    /// (the paper's two-subphase EKFAC "logging" column).
    fn fit(&mut self) -> Result<()> {
        if self.state.is_some() {
            return Ok(());
        }
        let man = &self.rt.manifest;
        let kfac: KfacFactors = fit_kfac(self.rt, self.train, self.params, 64)?;
        let mut ek = Ekfac::from_kfac(man, &kfac);
        let idx: Vec<usize> = (0..self.train.len()).collect();
        let kf = man.k_full;
        stream_rows(
            self.rt,
            "ekfac_log",
            self.train,
            &idx,
            self.params,
            Some(&ek.rotations_flat.clone()),
            man.proj_len_full,
            |rows, real| {
                ek.accumulate_corrected(rows, real, kf);
                Ok(())
            },
        )?;
        ek.finish_corrected(man);
        self.state = Some(ek);
        Ok(())
    }
}

impl Valuator for EkfacValuator<'_> {
    fn name(&self) -> String {
        "ekfac-if".into()
    }

    fn values(&mut self, test_indices: &[usize]) -> Result<Matrix> {
        self.fit()?;
        let man = &self.rt.manifest;
        let ek = self.state.as_ref().unwrap();
        let kf = man.k_full;
        // Rotated test gradients, preconditioned in the eigenbasis.
        let test_rot = collect_rows(
            self.rt,
            "ekfac_log",
            self.test,
            test_indices,
            self.params,
            Some(&ek.rotations_flat),
            man.proj_len_full,
            kf,
        )?;
        let mut pre = Vec::with_capacity(test_rot.data.len());
        for t in 0..test_indices.len() {
            pre.extend(ek.precondition(man, test_rot.row(t)));
        }
        let pre_m = Matrix::from_vec(test_indices.len(), kf, pre);

        // The expensive part: recompute rotated train grads for this query.
        let n_train = self.train.len();
        let idx: Vec<usize> = (0..n_train).collect();
        let mut out = Matrix::zeros(test_indices.len(), n_train);
        let mut col = 0usize;
        stream_rows(
            self.rt,
            "ekfac_log",
            self.train,
            &idx,
            self.params,
            Some(&ek.rotations_flat),
            man.proj_len_full,
            |rows, real| {
                let b = Matrix::from_vec(real, kf, rows.to_vec());
                let scores = pre_m.matmul_t(&b);
                for t in 0..test_indices.len() {
                    for j in 0..real {
                        out.data[t * n_train + col + j] = scores.at(t, j);
                    }
                }
                col += real;
                Ok(())
            },
        )?;
        Ok(out)
    }
}
