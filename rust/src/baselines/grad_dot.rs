//! Gradient dot-product baseline (TracIn-CP / Pruthi et al. at the final
//! checkpoint): value(te, tr) = <∇L(te), ∇L(tr)> over FULL gradients.
//!
//! Deliberately pays the O(b·n) full-gradient cost the paper's §2
//! analysis attributes to naive methods: test gradients are held in
//! memory, train gradients are recomputed batch-by-batch per call.

use anyhow::Result;

use crate::baselines::{collect_rows, stream_rows, Valuator};
use crate::linalg::Matrix;
use crate::model::dataset::Dataset;
use crate::runtime::Runtime;

pub struct GradDotValuator<'a> {
    pub rt: &'a Runtime,
    pub train: &'a Dataset<'a>,
    pub test: &'a Dataset<'a>,
    pub params: &'a [f32],
}

impl Valuator for GradDotValuator<'_> {
    fn name(&self) -> String {
        "grad-dot".into()
    }

    fn values(&mut self, test_indices: &[usize]) -> Result<Matrix> {
        let n = self.rt.manifest.n_params;
        let test_g = collect_rows(
            self.rt,
            "full_grad",
            self.test,
            test_indices,
            self.params,
            None,
            0,
            n,
        )?;
        let n_train = self.train.len();
        let idx: Vec<usize> = (0..n_train).collect();
        let mut out = Matrix::zeros(test_indices.len(), n_train);
        let mut col = 0usize;
        stream_rows(self.rt, "full_grad", self.train, &idx, self.params, None, 0, |rows, real| {
            let b = Matrix::from_vec(real, n, rows.to_vec());
            let scores = test_g.matmul_t(&b); // [nt, real]
            for t in 0..test_indices.len() {
                for j in 0..real {
                    out.data[t * n_train + col + j] = scores.at(t, j);
                }
            }
            col += real;
            Ok(())
        })?;
        Ok(out)
    }
}
